//! Columnar projection scans: read a *subset* of branches in **one pass**
//! over the file.
//!
//! "Optimizing ROOT IO For Analysis" (arXiv:1711.02659) observes that the
//! common analysis workload touches a small fraction of a tree's branches,
//! and that the dominant cost after decompression is the *seek pattern* of
//! per-branch reads. The PR-3 pipeline ([`super::read_pipeline`]) scans one
//! branch at a time: projecting k branches meant k independent sweeps over
//! the file, each skipping the other branches' baskets. This module
//! generalizes it to multi-branch jobs:
//!
//! ```text
//!  ProjectionPlan: merge k branches' BasketLoc lists, sort by file_offset
//!        │            (ONE monotonically-increasing read sweep)
//!        ▼
//!  BasketScan (PR-3 machinery: prefetch thread → N decode workers →
//!        │     in-submission-order delivery, pooled buffers)
//!        ▼
//!  ProjectionScan: reordering consumer keyed on (branch, basket seq) —
//!        │          routes interleaved baskets back to per-branch streams
//!        ▼
//!  ProjectionReader: per-branch event-order columns, or aligned row
//!                    batches via next_batch() (columns zipped per entry)
//! ```
//!
//! Plans can additionally be [sliced](ProjectionPlan::slice) to an **entry
//! range** `[first, last)` — the cluster-range read distributed and
//! partial-file workloads want (arXiv:1711.02659 §4): only the baskets
//! whose entry spans overlap the window are prefetched and decoded, and
//! the reader trims head/tail rows of boundary baskets so callers see
//! exactly the requested events. Entry spans come from the directory
//! ([`BasketLoc::entry_span`]); there is no wire-format change.
//!
//! Invariants (property-tested in `rust/tests/integration_projection.rs`
//! and `rust/tests/integration_entry_range.rs`):
//!  * a k-of-n projection is **byte-identical** to k independent serial
//!    [`TreeReader::read_branch`](crate::rfile::TreeReader::read_branch)
//!    calls, for any worker count and either prefetch order;
//!  * an entry-range projection is byte-identical to the full read
//!    followed by an in-memory slice — including empty windows, windows
//!    past EOF, and windows landing exactly on basket boundaries;
//!  * a corrupted basket in a projected branch fails the projection exactly
//!    like the serial reader — and does *not* fail projections that skip
//!    that branch (the columnar win: untouched branches are never read);
//!  * the [`PrefetchOrder::FileOffset`] plan issues one forward sweep:
//!    `ProjectionPlan::is_monotonic_sweep()` holds by construction (unit
//!    test below).

use crate::rfile::basket::BasketContent;
use crate::rfile::branch::{BranchType, Value};
use crate::rfile::meta::{push_gap, BasketLoc, GapSpan, TreeMeta};
use crate::rfile::reader::decode_values;
use anyhow::{anyhow, bail, Result};
use std::collections::{BTreeMap, HashMap, VecDeque};

use super::read_pipeline::{
    BasketScan, BasketStream, DamageRecord, DecodedBasket, Delivery, ParallelTreeReader, ScanMode,
};

/// Order in which a projection's merged basket list is handed to the
/// prefetcher.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefetchOrder {
    /// Sort the merged list by `file_offset`: one monotonically-increasing
    /// sweep over the file (no backward seeks). The default.
    FileOffset,
    /// Branch-major concatenation in projection order — the PR-3 behaviour
    /// of running one branch after another. Kept as the bench baseline for
    /// the seek-pattern comparison.
    Submission,
}

/// A merged, ordered prefetch plan over the baskets of a set of projected
/// branches. Build with [`ProjectionPlan::new`] (branch ids) or let
/// [`ParallelTreeReader::project`] resolve names for you; narrow it to an
/// entry range with [`ProjectionPlan::slice`].
#[derive(Debug, Clone)]
pub struct ProjectionPlan {
    branch_ids: Vec<u32>,
    locs: Vec<BasketLoc>,
    order: PrefetchOrder,
    /// `[first, last)` entry window when the plan was sliced; `None` means
    /// the whole tree. Stored unclamped — readers clamp to the tree's
    /// entry count.
    entry_range: Option<(u64, u64)>,
}

impl ProjectionPlan {
    /// Merge the basket directories of `branch_ids` into one prefetch plan.
    /// Rejects empty projections, duplicate ids, and ids outside the tree's
    /// schema.
    pub fn new(meta: &TreeMeta, branch_ids: &[u32], order: PrefetchOrder) -> Result<Self> {
        if branch_ids.is_empty() {
            bail!("empty projection: no branches selected");
        }
        let n = meta.branches.len() as u32;
        let mut seen = vec![false; n as usize];
        for &id in branch_ids {
            if id >= n {
                bail!("projection references branch {id}, tree has {n} branches");
            }
            if seen[id as usize] {
                bail!("duplicate branch {id} ('{}') in projection", meta.branches[id as usize].name);
            }
            seen[id as usize] = true;
        }
        // Branch-major merge first (each per-branch list is already ordered
        // by basket_index), then the offset sort if requested. The sort is
        // stable, so equal offsets (impossible in well-formed files, but
        // cheap to be deterministic about) keep submission order.
        let mut locs = meta.baskets_for_branches(branch_ids);
        if order == PrefetchOrder::FileOffset {
            locs.sort_by_key(|l| l.file_offset);
        }
        Ok(Self { branch_ids: branch_ids.to_vec(), locs, order, entry_range: None })
    }

    /// Narrow the plan to the baskets whose entry spans overlap
    /// `[first, last)` — the cluster-range trim for partial-file reads.
    /// Spans come from the directory's `first_entry`/`n_entries`
    /// ([`BasketLoc::entry_span`]), so no extra I/O happens here. Prefetch
    /// order is preserved (slicing an offset-sorted plan keeps it one
    /// forward sweep). Slicing an already-sliced plan intersects the
    /// ranges. A backwards or fully out-of-range window yields an empty
    /// plan, which reads zero baskets and zero entries.
    pub fn slice(&self, first: u64, last: u64) -> Self {
        let (first, last) = match self.entry_range {
            None => (first, last.max(first)),
            Some((a, b)) => {
                let lo = first.max(a);
                (lo, last.min(b).max(lo))
            }
        };
        let locs = self.locs.iter().copied().filter(|l| l.overlaps(first, last)).collect();
        Self {
            branch_ids: self.branch_ids.clone(),
            locs,
            order: self.order,
            entry_range: Some((first, last)),
        }
    }

    /// The `[first, last)` entry window this plan was sliced to, if any.
    pub fn entry_range(&self) -> Option<(u64, u64)> {
        self.entry_range
    }

    /// Resolve branch *names* to ids against `meta` (first error wins).
    pub fn resolve_names(meta: &TreeMeta, names: &[&str]) -> Result<Vec<u32>> {
        names
            .iter()
            .map(|name| {
                meta.branch_id(name)
                    .ok_or_else(|| anyhow!("no branch '{name}' in tree '{}'", meta.name))
            })
            .collect()
    }

    /// Plan covering the *first* basket of every branch, offset-sorted —
    /// the file-profiling sweep [`crate::runtime::analyze_tree`] rides
    /// (one forward pass instead of a branch-major walk).
    pub fn first_baskets(meta: &TreeMeta) -> Self {
        let mut firsts = meta.first_baskets();
        firsts.sort_by_key(|l| l.file_offset);
        let branch_ids = (0..meta.branches.len() as u32).collect();
        Self { branch_ids, locs: firsts, order: PrefetchOrder::FileOffset, entry_range: None }
    }

    /// The merged basket list in prefetch order.
    pub fn locs(&self) -> &[BasketLoc] {
        &self.locs
    }

    /// Projected branch ids in projection (slot) order.
    pub fn branch_ids(&self) -> &[u32] {
        &self.branch_ids
    }

    pub fn order(&self) -> PrefetchOrder {
        self.order
    }

    /// True iff the plan's file offsets never decrease — the prefetcher
    /// issues one forward sweep over the file. Holds by construction for
    /// [`PrefetchOrder::FileOffset`].
    pub fn is_monotonic_sweep(&self) -> bool {
        self.locs.windows(2).all(|w| w[0].file_offset <= w[1].file_offset)
    }

    /// Number of backward seeks the prefetcher would issue (positions where
    /// the next basket sits at a lower offset than the previous one).
    pub fn backward_seeks(&self) -> usize {
        self.locs.windows(2).filter(|w| w[1].file_offset < w[0].file_offset).count()
    }

    /// Total uncompressed bytes the plan covers (throughput denominator).
    pub fn logical_bytes(&self) -> u64 {
        self.locs.iter().map(|l| l.uncompressed_len as u64).sum()
    }

    /// Total compressed bytes the plan reads off the file.
    pub fn compressed_bytes(&self) -> u64 {
        self.locs.iter().map(|l| l.compressed_len as u64).sum()
    }
}

/// Per-slot reorder state: baskets of one projected branch. Salvage scans
/// park damage markers (`None` content) alongside intact baskets so the
/// per-branch index sequence stays contiguous even across casualties.
struct SlotState {
    branch_id: u32,
    /// Next basket_index to deliver for this branch.
    next_index: u32,
    /// Baskets that arrived ahead of their predecessor (keyed on
    /// basket_index). Empty in steady state for both standard plan orders —
    /// a branch's baskets sit at increasing offsets, so both sorts preserve
    /// each per-branch subsequence — but the reorder keeps delivery correct
    /// for *any* plan permutation (the concurrent scheduler's streams
    /// deliver in whatever order cache hits and worker skew produce).
    parked: BTreeMap<u32, (BasketLoc, Option<DecodedBasket>)>,
}

/// Multi-branch scan: wraps any [`BasketStream`] — the single-reader
/// [`BasketScan`] (the default) or a per-query
/// [`ServeStream`](super::scheduler::ServeStream) from the concurrent
/// scheduler — and re-routes its interleaved delivery into per-branch
/// streams, each in basket_index (= event) order. Yields
/// `(slot, BasketLoc, DecodedBasket)` where `slot` indexes the
/// projection's branch list.
pub struct ProjectionScan<S: BasketStream = BasketScan> {
    scan: S,
    slots: Vec<SlotState>,
    slot_of: HashMap<u32, usize>,
    /// Baskets unblocked by the last arrival, not yet handed out. `None`
    /// content is a salvage-mode damage marker.
    ready: VecDeque<(usize, BasketLoc, Option<DecodedBasket>)>,
    /// Set after a terminal error so the stream ends instead of re-erroring.
    failed: bool,
}

impl<S: BasketStream> ProjectionScan<S> {
    pub(crate) fn new(scan: S, plan: &ProjectionPlan) -> Self {
        // A sliced plan starts each branch mid-directory: the first
        // deliverable basket_index per branch is the smallest one in the
        // plan, not 0.
        let mut first_index: HashMap<u32, u32> = HashMap::new();
        for l in plan.locs() {
            let e = first_index.entry(l.branch_id).or_insert(l.basket_index);
            *e = (*e).min(l.basket_index);
        }
        let branch_ids = plan.branch_ids();
        let slots: Vec<SlotState> = branch_ids
            .iter()
            .map(|&id| SlotState {
                branch_id: id,
                next_index: first_index.get(&id).copied().unwrap_or(0),
                parked: BTreeMap::new(),
            })
            .collect();
        let slot_of = branch_ids.iter().enumerate().map(|(i, &id)| (id, i)).collect();
        Self { scan, slots, slot_of, ready: VecDeque::new(), failed: false }
    }

    /// Next delivery in per-branch order: `(slot, loc, Some(content))` for
    /// an intact basket, `(slot, loc, None)` for a salvage-mode damage
    /// marker (strict scans never produce one — damage is an `Err` there).
    /// `None` when the plan is exhausted.
    pub fn next_delivery(
        &mut self,
    ) -> Option<Result<(usize, BasketLoc, Option<DecodedBasket>)>> {
        if self.failed {
            return None;
        }
        loop {
            if let Some(item) = self.ready.pop_front() {
                return Some(Ok(item));
            }
            match self.scan.next_delivery() {
                None => {
                    if self.slots.iter().any(|s| !s.parked.is_empty()) {
                        self.failed = true;
                        return Some(Err(anyhow!(
                            "projection scan ended with undeliverable parked baskets \
                             (directory has non-contiguous basket indices)"
                        )));
                    }
                    return None;
                }
                Some(Err(e)) => {
                    self.failed = true;
                    return Some(Err(e));
                }
                Some(Ok(delivery)) => {
                    let (loc, content) = match delivery {
                        Delivery::Basket(loc, content) => (loc, Some(content)),
                        Delivery::Damaged(rec) => (rec.loc, None),
                    };
                    let Some(&slot) = self.slot_of.get(&loc.branch_id) else {
                        self.failed = true;
                        return Some(Err(anyhow!(
                            "scan delivered basket for unprojected branch {}",
                            loc.branch_id
                        )));
                    };
                    let (branch_id, basket_index) = (loc.branch_id, loc.basket_index);
                    let st = &mut self.slots[slot];
                    let duplicate = match basket_index.cmp(&st.next_index) {
                        std::cmp::Ordering::Less => true,
                        std::cmp::Ordering::Equal => {
                            st.next_index += 1;
                            self.ready.push_back((slot, loc, content));
                            // Parked successors become deliverable in order.
                            while let Some((l, c)) = st.parked.remove(&st.next_index) {
                                st.next_index += 1;
                                self.ready.push_back((slot, l, c));
                            }
                            false
                        }
                        std::cmp::Ordering::Greater => {
                            st.parked.insert(basket_index, (loc, content)).is_some()
                        }
                    };
                    if duplicate {
                        self.failed = true;
                        return Some(Err(anyhow!(
                            "duplicate basket ({branch_id},{basket_index}) in projection plan"
                        )));
                    }
                }
            }
        }
    }

    /// Next intact basket in per-branch order (see type docs), or `None`
    /// when the plan is exhausted. Decode errors surface on the basket that
    /// failed, exactly like [`BasketScan::next_basket`]; salvage-mode
    /// damage markers are skipped (use
    /// [`next_delivery`](ProjectionScan::next_delivery) to observe them).
    pub fn next_basket(&mut self) -> Option<Result<(usize, BasketLoc, DecodedBasket)>> {
        loop {
            match self.next_delivery()? {
                Ok((slot, loc, Some(content))) => return Some(Ok((slot, loc, content))),
                Ok((_, _, None)) => continue,
                Err(e) => return Some(Err(e)),
            }
        }
    }

    /// Return a consumed basket's buffers to the underlying scan's pools
    /// (see [`BasketScan::recycle`]); shared cache-backed payloads are
    /// simply dropped.
    pub fn recycle(&self, content: DecodedBasket) {
        self.scan.recycle(content);
    }

    /// Branch id behind a delivery slot.
    pub fn branch_id(&self, slot: usize) -> u32 {
        self.slots[slot].branch_id
    }

    /// The underlying scan's failure-handling mode.
    pub fn mode(&self) -> ScanMode {
        self.scan.mode()
    }

    /// Damage reports from the underlying scan (salvage mode; read-level
    /// damage only — decode-level casualties are tracked by the reader).
    pub fn damage(&self) -> &[DamageRecord] {
        self.scan.damage()
    }
}

/// Read statistics for one projected branch (CLI `--branches` table).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BranchReadStats {
    pub branch_id: u32,
    pub name: String,
    pub baskets: u64,
    pub entries: u64,
    pub compressed_bytes: u64,
    pub logical_bytes: u64,
    /// Baskets skipped as unreadable/undecodable (salvage mode only;
    /// always 0 in strict mode, where damage fails the projection).
    pub damaged_baskets: u64,
    /// Entries lost to damaged baskets, clamped to the projection window.
    pub damaged_entries: u64,
}

/// An aligned batch of projected rows: `rows[i][slot]` is the value of the
/// projection's `slot`-th branch at entry `first_entry + i`.
#[derive(Debug, Clone, PartialEq)]
pub struct RowBatch {
    pub first_entry: u64,
    pub rows: Vec<Vec<Value>>,
}

impl RowBatch {
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

/// Event-order consumer over a [`ProjectionScan`]: buffers each branch's
/// decoded values and zips them into aligned [`RowBatch`]es
/// ([`ProjectionReader::next_batch`]) or whole per-branch columns
/// ([`ProjectionReader::read_columns`]).
///
/// ```
/// use rootio::compression::{Algorithm, Settings};
/// use rootio::coordinator::{ParallelTreeReader, ReadAhead};
/// use rootio::gen::synthetic;
/// use rootio::rfile::write_tree_serial;
///
/// let path = std::env::temp_dir().join(format!("rootio_doc_proj_{}.rfil", std::process::id()));
/// let events = synthetic::events(300, 11);
/// write_tree_serial(&path, "Events", synthetic::schema(),
///                   Settings::new(Algorithm::Lz4, 1), 2048, events.iter().cloned()).unwrap();
///
/// let reader = ParallelTreeReader::open(&path, ReadAhead::with_workers(2)).unwrap();
/// // Project 2 of the 12 branches: one pass over the file, other branches
/// // are never read or decompressed.
/// let mut proj = reader.project(&["px", "nTrack"]).unwrap();
/// let mut rows = 0usize;
/// while let Some(batch) = proj.next_batch() {
///     let batch = batch.unwrap();
///     assert!(batch.rows.iter().all(|row| row.len() == 2));
///     rows += batch.len();
/// }
/// assert_eq!(rows, 300);
/// std::fs::remove_file(&path).ok();
/// ```
pub struct ProjectionReader<S: BasketStream = BasketScan> {
    scan: ProjectionScan<S>,
    types: Vec<BranchType>,
    stats: Vec<BranchReadStats>,
    /// First entry of the projected window (0 for whole-tree projections).
    start: u64,
    /// One past the last entry of the window (tree entry count when whole).
    end: u64,
    /// Entries this projection emits: `end - start`.
    n_entries: u64,
    /// Decoded-but-unemitted values per slot (front = oldest entry).
    bufs: Vec<VecDeque<Value>>,
    value_scratch: Vec<Value>,
    emitted: u64,
    max_batch_rows: Option<usize>,
    /// Latched after any error: a failed basket's values never reached
    /// `bufs`, so continuing would emit misaligned rows. The stream ends
    /// instead.
    failed: bool,
    /// First terminal error (`{:#}` formatted), cited by later calls so
    /// "projection already failed" says *what* failed.
    fail_context: Option<String>,
    /// Salvage-only state below; all empty/zero in strict mode.
    /// Per-slot run-length segments of the entry stream: `(rows, present)`
    /// — present rows are backed by `bufs`, absent rows were lost to
    /// damage. Aligned across slots by construction (every basket covers
    /// its directory span, damaged or not).
    segs: Vec<VecDeque<(u64, bool)>>,
    /// Row-level gaps (absolute entry ids): spans where at least one
    /// projected branch was damaged, merged when adjacent.
    gaps: Vec<GapSpan>,
    /// Per-slot gaps (absolute entry ids) for column-shaped salvage reads.
    slot_gaps: Vec<Vec<GapSpan>>,
    /// Decode-level casualties found by this reader (read-level ones live
    /// in the scan).
    local_damage: Vec<DamageRecord>,
    /// Entries dropped from the row stream because some slot was damaged.
    skipped: u64,
}

impl<S: BasketStream> ProjectionReader<S> {
    pub(crate) fn new(scan: ProjectionScan<S>, meta: &TreeMeta, plan: &ProjectionPlan) -> Self {
        let branch_ids = plan.branch_ids();
        let types = branch_ids.iter().map(|&id| meta.branches[id as usize].ty).collect();
        let stats = branch_ids
            .iter()
            .map(|&id| BranchReadStats {
                branch_id: id,
                name: meta.branches[id as usize].name.clone(),
                ..BranchReadStats::default()
            })
            .collect();
        let bufs = branch_ids.iter().map(|_| VecDeque::new()).collect();
        let segs = branch_ids.iter().map(|_| VecDeque::new()).collect();
        let slot_gaps = branch_ids.iter().map(|_| Vec::new()).collect();
        let (start, end) = match plan.entry_range() {
            None => (0, meta.n_entries),
            Some((a, b)) => meta.clamp_entry_range(a, b),
        };
        Self {
            scan,
            types,
            stats,
            start,
            end,
            n_entries: end - start,
            bufs,
            value_scratch: Vec::new(),
            emitted: 0,
            max_batch_rows: None,
            failed: false,
            fail_context: None,
            segs,
            gaps: Vec::new(),
            slot_gaps,
            local_damage: Vec::new(),
            skipped: 0,
        }
    }

    fn latch_failure(&mut self, e: &anyhow::Error) {
        self.failed = true;
        if self.fail_context.is_none() {
            self.fail_context = Some(format!("{e:#}"));
        }
    }

    /// Cap the row count of each [`RowBatch`] (default: uncapped — batch
    /// boundaries fall wherever basket alignment puts them).
    pub fn set_max_batch_rows(&mut self, rows: usize) {
        self.max_batch_rows = if rows == 0 { None } else { Some(rows) };
    }

    /// Per-branch read statistics accumulated so far (complete once the
    /// projection is drained).
    pub fn branch_stats(&self) -> &[BranchReadStats] {
        &self.stats
    }

    /// Entries emitted through [`ProjectionReader::next_batch`] so far.
    pub fn entries_emitted(&self) -> u64 {
        self.emitted
    }

    /// The absolute entry window `[first, last)` this projection covers —
    /// the whole tree unless the plan was sliced, already clamped to the
    /// tree's entry count.
    pub fn entry_range(&self) -> (u64, u64) {
        (self.start, self.end)
    }

    fn note_basket(&mut self, slot: usize, loc: &BasketLoc, content: &BasketContent) {
        let st = &mut self.stats[slot];
        st.baskets += 1;
        st.entries += content.n_entries as u64;
        st.compressed_bytes += loc.compressed_len as u64;
        st.logical_bytes += (content.data.len() + 4 * content.offsets.len()) as u64;
    }

    /// Entries consumed from the window so far (emitted rows plus, in
    /// salvage mode, rows dropped to damage).
    fn consumed(&self) -> u64 {
        self.emitted + self.skipped
    }

    /// Pull baskets until every projected branch has at least one pending
    /// value, then emit the aligned rows. `None` once all entries are out.
    ///
    /// Strict mode: an error is terminal — the failed basket's values never
    /// reached the column buffers, so the stream ends (further calls return
    /// `None`) rather than emitting misaligned rows.
    ///
    /// Salvage mode: entry spans where *any* projected branch is damaged
    /// are dropped from the row stream and reported as [`GapSpan`]s
    /// ([`ProjectionReader::gaps`]); batches still carry absolute
    /// `first_entry` ids, so consumers see exactly where the holes are.
    pub fn next_batch(&mut self) -> Option<Result<RowBatch>> {
        if self.failed || self.consumed() >= self.n_entries {
            return None;
        }
        if self.scan.mode() == ScanMode::Salvage {
            let r = self.next_batch_salvage();
            if let Some(Err(e)) = &r {
                self.latch_failure(e);
            }
            return r;
        }
        loop {
            let avail = self.bufs.iter().map(|b| b.len()).min().unwrap_or(0);
            if avail > 0 {
                return Some(Ok(self.emit_rows(avail)));
            }
            match self.scan.next_basket() {
                Some(Ok((slot, loc, content))) => {
                    self.value_scratch.clear();
                    if let Err(e) = decode_values(&content, self.types[slot], &mut self.value_scratch)
                    {
                        self.latch_failure(&e);
                        return Some(Err(e));
                    }
                    self.note_basket(slot, &loc, &content);
                    self.scan.recycle(content);
                    // Boundary baskets of a sliced projection decode whole
                    // but contribute only the rows inside the window.
                    let (from, to) = loc.trim_bounds(self.start, self.end);
                    self.bufs[slot].extend(self.value_scratch.drain(..to).skip(from));
                }
                Some(Err(e)) => {
                    self.latch_failure(&e);
                    return Some(Err(e));
                }
                None => {
                    let e = anyhow!(
                        "projection scan ended after {} of {} entries",
                        self.emitted,
                        self.n_entries
                    );
                    self.latch_failure(&e);
                    return Some(Err(e));
                }
            }
        }
    }

    /// Salvage-mode batch loop over the per-slot run-length segments: a
    /// chunk (the min front-segment length across slots) where every slot
    /// is present becomes a row batch; a chunk where any slot is absent
    /// becomes a gap (present slots' values for it are discarded — rows
    /// need all slots).
    fn next_batch_salvage(&mut self) -> Option<Result<RowBatch>> {
        loop {
            while !self.segs.is_empty() && self.segs.iter().all(|s| !s.is_empty()) {
                let chunk = self.segs.iter().map(|s| s.front().unwrap().0).min().unwrap();
                let all_present = self.segs.iter().all(|s| s.front().unwrap().1);
                if all_present {
                    let take = match self.max_batch_rows {
                        Some(cap) => chunk.min(cap as u64),
                        None => chunk,
                    };
                    self.consume_segments(take);
                    return Some(Ok(self.emit_rows(take as usize)));
                }
                // Damaged chunk: drop what the intact slots decoded for it.
                for (slot, segs) in self.segs.iter_mut().enumerate() {
                    if segs.front().unwrap().1 {
                        self.bufs[slot].drain(..chunk as usize);
                    }
                }
                let first_entry = self.start + self.consumed();
                self.consume_segments(chunk);
                push_gap(&mut self.gaps, GapSpan { first_entry, n_entries: chunk });
                self.skipped += chunk;
                if self.consumed() >= self.n_entries {
                    return None;
                }
            }
            match self.pull_salvage() {
                Err(e) => return Some(Err(e)),
                Ok(true) => {}
                Ok(false) => {
                    if self.consumed() >= self.n_entries {
                        return None;
                    }
                    return Some(Err(anyhow!(
                        "projection scan ended after {} of {} entries ({} skipped as damaged)",
                        self.emitted,
                        self.n_entries,
                        self.skipped
                    )));
                }
            }
        }
    }

    /// Subtract `n` rows from the front segment of every slot, popping
    /// exhausted segments.
    fn consume_segments(&mut self, n: u64) {
        for segs in self.segs.iter_mut() {
            let front = segs.front_mut().expect("consume with a front segment per slot");
            debug_assert!(front.0 >= n);
            front.0 -= n;
            if front.0 == 0 {
                segs.pop_front();
            }
        }
    }

    /// Append a `(rows, present)` run to a slot's segment queue, merging
    /// with the tail when the presence flag matches.
    fn push_seg(&mut self, slot: usize, rows: u64, present: bool) {
        if rows == 0 {
            return;
        }
        if let Some(tail) = self.segs[slot].back_mut() {
            if tail.1 == present {
                tail.0 += rows;
                return;
            }
        }
        self.segs[slot].push_back((rows, present));
    }

    /// Record a damaged basket against its slot's stats and gap list.
    fn note_damage(&mut self, slot: usize, loc: &BasketLoc) {
        if let Some(g) = loc.gap_within(self.start, self.end) {
            self.stats[slot].damaged_baskets += 1;
            self.stats[slot].damaged_entries += g.n_entries;
            push_gap(&mut self.slot_gaps[slot], g);
        }
    }

    /// Pull one delivery in salvage mode, updating buffers, segments,
    /// stats, and damage lists. `Ok(false)` = plan exhausted.
    fn pull_salvage(&mut self) -> Result<bool> {
        match self.scan.next_delivery() {
            None => Ok(false),
            Some(Err(e)) => Err(e),
            Some(Ok((slot, loc, maybe_content))) => {
                let (from, to) = loc.trim_bounds(self.start, self.end);
                let rows = (to - from) as u64;
                match maybe_content {
                    Some(content) => {
                        self.value_scratch.clear();
                        match decode_values(&content, self.types[slot], &mut self.value_scratch) {
                            Ok(()) => {
                                self.note_basket(slot, &loc, &content);
                                self.bufs[slot].extend(self.value_scratch.drain(..to).skip(from));
                                self.push_seg(slot, rows, true);
                            }
                            Err(e) => {
                                // Decompressed fine but the payload is
                                // structurally corrupt: a decode-level
                                // casualty, same treatment as a read-level
                                // one.
                                self.local_damage.push(DamageRecord {
                                    loc,
                                    branch: self.stats[slot].name.clone(),
                                    error: format!("{e:#}"),
                                });
                                self.note_damage(slot, &loc);
                                self.push_seg(slot, rows, false);
                            }
                        }
                        self.scan.recycle(content);
                    }
                    None => {
                        self.note_damage(slot, &loc);
                        self.push_seg(slot, rows, false);
                    }
                }
                Ok(true)
            }
        }
    }

    fn emit_rows(&mut self, mut avail: usize) -> RowBatch {
        if let Some(cap) = self.max_batch_rows {
            avail = avail.min(cap);
        }
        // Absolute entry id: offset by the window start for sliced reads
        // (and by skipped damage spans in salvage mode).
        let first_entry = self.start + self.consumed();
        let k = self.bufs.len();
        let mut rows: Vec<Vec<Value>> = (0..avail).map(|_| Vec::with_capacity(k)).collect();
        for buf in self.bufs.iter_mut() {
            for row in rows.iter_mut() {
                row.push(buf.pop_front().expect("avail is min over buffer lengths"));
            }
        }
        self.emitted += avail as u64;
        RowBatch { first_entry, rows }
    }

    /// Row-level gaps (absolute entry ids) dropped from the batch stream so
    /// far: spans where at least one projected branch was damaged. Salvage
    /// mode only; always empty in strict mode. Complete once the batch
    /// stream is drained.
    pub fn gaps(&self) -> &[GapSpan] {
        &self.gaps
    }

    /// Per-branch gaps (absolute entry ids) for projection slot `slot` —
    /// finer-grained than [`gaps`](ProjectionReader::gaps), which unions
    /// the slots.
    pub fn branch_gaps(&self, slot: usize) -> &[GapSpan] {
        &self.slot_gaps[slot]
    }

    /// Entries dropped from the row stream because some projected branch
    /// was damaged there (salvage mode only).
    pub fn entries_skipped(&self) -> u64 {
        self.skipped
    }

    /// All damage observed so far: read-level casualties from the scan,
    /// then decode-level ones found by this reader.
    pub fn damage(&self) -> Vec<DamageRecord> {
        let mut all = self.scan.damage().to_vec();
        all.extend(self.local_damage.iter().cloned());
        all
    }

    /// Drain the projection into whole per-branch columns (event order, one
    /// `Vec<Value>` per projected branch, in projection order). Covers the
    /// window entries not yet emitted through
    /// [`ProjectionReader::next_batch`]; verifies every column reaches the
    /// projection window's entry count (the whole tree unless the plan was
    /// sliced). Errors are terminal, like
    /// [`ProjectionReader::next_batch`]'s.
    ///
    /// Salvage mode: each branch's column holds its *intact* values only
    /// (damaged entries elided per branch), so columns may differ in
    /// length; [`branch_gaps`](ProjectionReader::branch_gaps) says which
    /// absolute entries each column is missing. Requires a fresh reader
    /// (no batches emitted yet).
    pub fn read_columns(&mut self) -> Result<Vec<Vec<Value>>> {
        if self.failed {
            match &self.fail_context {
                Some(ctx) => bail!(
                    "projection already failed ({ctx}); open a new projection to retry"
                ),
                None => bail!("projection already failed; open a new projection to retry"),
            }
        }
        let r = if self.scan.mode() == ScanMode::Salvage {
            self.read_columns_salvage()
        } else {
            self.read_columns_inner()
        };
        if let Err(e) = &r {
            self.latch_failure(e);
        }
        r
    }

    /// Salvage-mode column drain: per-branch intact values, per-branch gap
    /// accounting, no row alignment.
    fn read_columns_salvage(&mut self) -> Result<Vec<Vec<Value>>> {
        if self.emitted > 0 || self.skipped > 0 || self.bufs.iter().any(|b| !b.is_empty()) {
            bail!(
                "salvage read_columns needs a fresh projection: {} entries already pulled \
                 through the batch stream",
                self.consumed()
            );
        }
        while self.pull_salvage()? {}
        let mut columns: Vec<Vec<Value>> = Vec::with_capacity(self.bufs.len());
        for b in self.bufs.iter_mut() {
            columns.push(b.drain(..).collect());
        }
        for (slot, col) in columns.iter().enumerate() {
            let expect = self.n_entries - self.stats[slot].damaged_entries;
            if col.len() as u64 != expect {
                bail!(
                    "branch {} ('{}'): {} intact entries decoded, expected {expect} \
                     ({} damaged of {})",
                    self.stats[slot].branch_id,
                    self.stats[slot].name,
                    col.len(),
                    self.stats[slot].damaged_entries,
                    self.n_entries
                );
            }
        }
        // The column drain bypasses the row stream; mark the window
        // consumed so next_batch() reports exhaustion, not a truncated scan.
        self.emitted = self.n_entries;
        self.segs.iter_mut().for_each(|s| s.clear());
        Ok(columns)
    }

    fn read_columns_inner(&mut self) -> Result<Vec<Vec<Value>>> {
        let expect = self.n_entries - self.emitted;
        let mut columns: Vec<Vec<Value>> = self
            .bufs
            .iter_mut()
            .map(|b| {
                let mut col = Vec::with_capacity(expect as usize);
                col.extend(b.drain(..));
                col
            })
            .collect();
        while let Some(item) = self.scan.next_basket() {
            let (slot, loc, content) = item?;
            self.note_basket(slot, &loc, &content);
            let (from, to) = loc.trim_bounds(self.start, self.end);
            if from == 0 && to == loc.n_entries as usize {
                // Interior basket: decode straight into the column.
                decode_values(&content, self.types[slot], &mut columns[slot])?;
            } else {
                // Boundary basket of a sliced window: decode whole, keep
                // only the rows inside `[start, end)`.
                self.value_scratch.clear();
                decode_values(&content, self.types[slot], &mut self.value_scratch)?;
                columns[slot].extend(self.value_scratch.drain(..to).skip(from));
            }
            self.scan.recycle(content);
        }
        for (slot, col) in columns.iter().enumerate() {
            if col.len() as u64 != expect {
                bail!(
                    "branch {} ('{}'): {} entries decoded, expected {expect}",
                    self.stats[slot].branch_id,
                    self.stats[slot].name,
                    col.len()
                );
            }
        }
        self.emitted = self.n_entries;
        Ok(columns)
    }
}

impl ParallelTreeReader {
    /// Project `branches` (by name) through one offset-sorted pipelined
    /// pass — see [`ProjectionReader`]. The scan starts immediately.
    pub fn project(&self, branches: &[&str]) -> Result<ProjectionReader> {
        let ids = ProjectionPlan::resolve_names(&self.meta, branches)?;
        let plan = ProjectionPlan::new(&self.meta, &ids, PrefetchOrder::FileOffset)?;
        self.project_plan(&plan)
    }

    /// Project `branches` over the entry window `[range.start, range.end)`
    /// only: the plan is [sliced](ProjectionPlan::slice) to the baskets
    /// overlapping the window, the pipeline decodes only those, and the
    /// reader trims head/tail rows of boundary baskets so callers see
    /// exactly the requested entries. Ranges are clamped to the tree
    /// (past-EOF and empty windows yield zero rows, not errors).
    pub fn project_range(
        &self,
        branches: &[&str],
        range: std::ops::Range<u64>,
    ) -> Result<ProjectionReader> {
        let ids = ProjectionPlan::resolve_names(&self.meta, branches)?;
        let plan = ProjectionPlan::new(&self.meta, &ids, PrefetchOrder::FileOffset)?
            .slice(range.start, range.end);
        self.project_plan(&plan)
    }

    /// Project with an explicit, pre-built [`ProjectionPlan`] (choose the
    /// prefetch order, slice an entry range, inspect the sweep, reuse a
    /// plan across readers).
    pub fn project_plan(&self, plan: &ProjectionPlan) -> Result<ProjectionReader> {
        self.project_plan_with_mode(plan, ScanMode::Strict)
    }

    /// [`project`](Self::project) with an explicit failure-handling mode.
    /// [`ScanMode::Salvage`] turns damaged baskets into reported gaps
    /// instead of errors — see [`ProjectionReader::gaps`],
    /// [`ProjectionReader::damage`].
    pub fn project_with_mode(&self, branches: &[&str], mode: ScanMode) -> Result<ProjectionReader> {
        let ids = ProjectionPlan::resolve_names(&self.meta, branches)?;
        let plan = ProjectionPlan::new(&self.meta, &ids, PrefetchOrder::FileOffset)?;
        self.project_plan_with_mode(&plan, mode)
    }

    /// Convenience for
    /// [`project_with_mode`](Self::project_with_mode)`(branches, ScanMode::Salvage)`.
    pub fn project_salvage(&self, branches: &[&str]) -> Result<ProjectionReader> {
        self.project_with_mode(branches, ScanMode::Salvage)
    }

    /// [`project_plan`](Self::project_plan) with an explicit
    /// failure-handling mode.
    pub fn project_plan_with_mode(
        &self,
        plan: &ProjectionPlan,
        mode: ScanMode,
    ) -> Result<ProjectionReader> {
        let scan = self.scan_with_mode(plan.locs().to_vec(), mode)?;
        Ok(ProjectionReader::new(ProjectionScan::new(scan, plan), &self.meta, plan))
    }

    /// One-call multi-branch read: per-branch event-order columns for
    /// `branches`, byte-identical to k independent
    /// [`TreeReader::read_branch`](crate::rfile::TreeReader::read_branch)
    /// calls but issued as a single offset-sorted sweep.
    pub fn read_branches(&self, branches: &[&str]) -> Result<Vec<Vec<Value>>> {
        self.project(branches)?.read_columns()
    }

    /// Project **every** branch over the entry window
    /// `[range.start, range.end)` — the all-branch entry-range surface.
    /// Skips the branch-name round-trip [`project_range`]
    /// (Self::project_range) does: slot `i` is branch id `i` directly, in
    /// schema order. The returned reader serves aligned row batches
    /// ([`ProjectionReader::next_batch`], absolute entry ids) or whole
    /// columns, exactly like any other projection.
    pub fn project_all_range(&self, range: std::ops::Range<u64>) -> Result<ProjectionReader> {
        let ids: Vec<u32> = (0..self.meta.branches.len() as u32).collect();
        let plan = ProjectionPlan::new(&self.meta, &ids, PrefetchOrder::FileOffset)?
            .slice(range.start, range.end);
        self.project_plan(&plan)
    }

    /// Row-wise reconstruction of the entry window
    /// `[range.start, range.end)` across **all** branches — the windowed
    /// twin of [`read_all_events`](Self::read_all_events), byte-identical
    /// to [`TreeReader::read_all_events_range`]. Only baskets overlapping
    /// the window are read and decoded; the range is clamped to the tree.
    pub fn read_all_events_range(&self, range: std::ops::Range<u64>) -> Result<Vec<Vec<Value>>> {
        let mut proj = self.project_all_range(range)?;
        let columns = proj.read_columns()?;
        let n_branches = columns.len();
        let n = columns.first().map(|c| c.len()).unwrap_or(0);
        let mut events: Vec<Vec<Value>> = (0..n).map(|_| Vec::with_capacity(n_branches)).collect();
        for col in columns {
            for (ev, v) in events.iter_mut().zip(col) {
                ev.push(v);
            }
        }
        Ok(events)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::{Algorithm, Settings};
    use crate::coordinator::ReadAhead;
    use crate::gen::synthetic;
    use crate::rfile::{write_tree_serial, TreeReader};
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("rootio_proj_{}_{}", std::process::id(), name));
        p
    }

    fn write_sample(name: &str, n: usize, basket: usize) -> PathBuf {
        let path = tmp(name);
        let events = synthetic::events(n, 0x13AF);
        write_tree_serial(
            &path,
            "Events",
            synthetic::schema(),
            Settings::new(Algorithm::Lz4, 1),
            basket,
            events.iter().cloned(),
        )
        .unwrap();
        path
    }

    #[test]
    fn offset_sorted_plan_is_one_monotonic_sweep() {
        let path = write_sample("plan", 400, 1024);
        let reader = TreeReader::open(&path).unwrap();
        let ids: Vec<u32> = vec![0, 3, 7, 8];
        let plan = ProjectionPlan::new(&reader.meta, &ids, PrefetchOrder::FileOffset).unwrap();
        assert!(plan.is_monotonic_sweep(), "offset-sorted plan must never seek backward");
        assert_eq!(plan.backward_seeks(), 0);
        assert_eq!(
            plan.locs().len(),
            ids.iter().map(|&b| reader.meta.baskets_for(b).len()).sum::<usize>()
        );

        // The branch-major submission plan re-sweeps the file once per
        // branch: with multiple interleaved baskets per branch it must seek
        // backward at least once per branch boundary.
        let sub = ProjectionPlan::new(&reader.meta, &ids, PrefetchOrder::Submission).unwrap();
        assert!(!sub.is_monotonic_sweep());
        assert!(sub.backward_seeks() >= ids.len() - 1, "seeks: {}", sub.backward_seeks());
        assert_eq!(plan.logical_bytes(), sub.logical_bytes());

        // First-basket profiling plan: also one forward sweep.
        assert!(ProjectionPlan::first_baskets(&reader.meta).is_monotonic_sweep());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn plan_rejects_bad_projections() {
        let path = write_sample("plan_bad", 50, 4096);
        let reader = TreeReader::open(&path).unwrap();
        assert!(ProjectionPlan::new(&reader.meta, &[], PrefetchOrder::FileOffset).is_err());
        assert!(ProjectionPlan::new(&reader.meta, &[0, 0], PrefetchOrder::FileOffset).is_err());
        assert!(ProjectionPlan::new(&reader.meta, &[99], PrefetchOrder::FileOffset).is_err());
        assert!(ProjectionPlan::resolve_names(&reader.meta, &["nope"]).is_err());
        assert_eq!(ProjectionPlan::resolve_names(&reader.meta, &["px", "nTrack"]).unwrap(), vec![3, 6]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn projection_columns_match_serial_and_stats_add_up() {
        let path = write_sample("cols", 500, 1024);
        let mut serial = TreeReader::open(&path).unwrap();
        let par = ParallelTreeReader::open(&path, ReadAhead { workers: 2, depth: 3 }).unwrap();
        let names = ["Track_pt", "px", "is_good"];
        let mut proj = par.project(&names).unwrap();
        let columns = proj.read_columns().unwrap();
        assert_eq!(columns.len(), names.len());
        for (slot, name) in names.iter().enumerate() {
            let id = serial.branch_id(name).unwrap();
            assert_eq!(columns[slot], serial.read_branch(id).unwrap(), "branch {name}");
            let st = &proj.branch_stats()[slot];
            assert_eq!(st.name, *name);
            assert_eq!(st.baskets, serial.baskets_for(id).len() as u64);
            assert_eq!(st.entries, serial.meta.n_entries);
            assert_eq!(
                st.compressed_bytes,
                serial.baskets_for(id).iter().map(|l| l.compressed_len as u64).sum::<u64>()
            );
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn batches_zip_columns_in_entry_order() {
        let path = write_sample("batch", 300, 512);
        let mut serial = TreeReader::open(&path).unwrap();
        let par = ParallelTreeReader::open(&path, ReadAhead { workers: 3, depth: 2 }).unwrap();
        let names = ["event_id", "Track_charge"];
        let cols: Vec<Vec<Value>> = names
            .iter()
            .map(|n| serial.read_branch(serial.branch_id(n).unwrap()).unwrap())
            .collect();
        let mut proj = par.project(&names).unwrap();
        proj.set_max_batch_rows(37); // force uneven batch boundaries
        let mut entry = 0u64;
        while let Some(batch) = proj.next_batch() {
            let batch = batch.unwrap();
            assert_eq!(batch.first_entry, entry);
            assert!(batch.len() <= 37);
            assert!(!batch.is_empty());
            for (i, row) in batch.rows.iter().enumerate() {
                let e = (entry + i as u64) as usize;
                assert_eq!(row.len(), names.len());
                for (slot, v) in row.iter().enumerate() {
                    assert_eq!(*v, cols[slot][e], "entry {e} slot {slot}");
                }
            }
            entry += batch.len() as u64;
        }
        assert_eq!(entry, serial.meta.n_entries);
        assert_eq!(proj.entries_emitted(), entry);
        // Exhausted: further calls keep returning None.
        assert!(proj.next_batch().is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sliced_plans_keep_only_overlapping_baskets() {
        let path = write_sample("slice_plan", 400, 1024);
        let reader = TreeReader::open(&path).unwrap();
        let ids = ProjectionPlan::resolve_names(&reader.meta, &["px", "Track_pt"]).unwrap();
        let plan = ProjectionPlan::new(&reader.meta, &ids, PrefetchOrder::FileOffset).unwrap();
        let n = reader.meta.n_entries;
        let sliced = plan.slice(n / 4, 3 * n / 4);
        assert!(sliced.locs().iter().all(|l| l.overlaps(n / 4, 3 * n / 4)));
        assert!(sliced.locs().len() < plan.locs().len());
        assert!(sliced.is_monotonic_sweep(), "slicing must preserve the forward sweep");
        assert_eq!(sliced.entry_range(), Some((n / 4, 3 * n / 4)));
        // Every in-range basket of each projected branch is present.
        for &id in &ids {
            assert_eq!(
                sliced.locs().iter().filter(|l| l.branch_id == id).count(),
                reader.meta.baskets_for_range(id, n / 4, 3 * n / 4).len(),
                "branch {id}"
            );
        }
        // Slicing a slice intersects the windows.
        let nested = sliced.slice(0, n / 2);
        assert_eq!(nested.entry_range(), Some((n / 4, n / 2)));
        assert!(nested.locs().iter().all(|l| l.overlaps(n / 4, n / 2)));
        // Empty and out-of-range windows yield empty plans.
        assert!(plan.slice(10, 10).locs().is_empty());
        assert!(plan.slice(n + 5, n + 50).locs().is_empty());
        assert!(plan.slice(30, 10).locs().is_empty(), "backwards window is empty");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn project_range_matches_in_memory_slice() {
        let path = write_sample("range_cols", 500, 1024);
        let mut serial = TreeReader::open(&path).unwrap();
        let par = ParallelTreeReader::open(&path, ReadAhead { workers: 2, depth: 3 }).unwrap();
        let names = ["event_id", "Track_pt"];
        let full: Vec<Vec<Value>> = names
            .iter()
            .map(|n| serial.read_branch(serial.branch_id(n).unwrap()).unwrap())
            .collect();
        let n = serial.meta.n_entries;
        for (a, b) in [(0, n), (n / 3, 2 * n / 3), (0, 1), (n - 1, n), (7, 7), (n, n + 9)] {
            let mut proj = par.project_range(&names, a..b).unwrap();
            let cols = proj.read_columns().unwrap();
            let (ca, cb) = (a.min(n) as usize, b.min(n).max(a.min(n)) as usize);
            for (slot, col) in cols.iter().enumerate() {
                assert_eq!(col.as_slice(), &full[slot][ca..cb], "range [{a},{b}) slot {slot}");
            }
            assert_eq!(proj.entry_range(), (ca as u64, cb as u64));
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn ranged_batches_report_absolute_entries() {
        let path = write_sample("range_batch", 300, 512);
        let mut serial = TreeReader::open(&path).unwrap();
        let par = ParallelTreeReader::open(&path, ReadAhead { workers: 2, depth: 2 }).unwrap();
        let names = ["py", "label"];
        let cols: Vec<Vec<Value>> = names
            .iter()
            .map(|n| serial.read_branch(serial.branch_id(n).unwrap()).unwrap())
            .collect();
        let (a, b) = (41u64, 227u64);
        let mut proj = par.project_range(&names, a..b).unwrap();
        proj.set_max_batch_rows(23);
        let mut entry = a;
        while let Some(batch) = proj.next_batch() {
            let batch = batch.unwrap();
            assert_eq!(batch.first_entry, entry, "batches carry absolute entry ids");
            for (i, row) in batch.rows.iter().enumerate() {
                let e = (entry + i as u64) as usize;
                for (slot, v) in row.iter().enumerate() {
                    assert_eq!(*v, cols[slot][e], "entry {e} slot {slot}");
                }
            }
            entry += batch.len() as u64;
        }
        assert_eq!(entry, b);
        assert_eq!(proj.entries_emitted(), b - a);
        assert!(proj.next_batch().is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn salvage_projection_skips_damaged_spans() {
        let path = tmp("salvage_proj");
        let events = synthetic::events(400, 0x5A17);
        write_tree_serial(
            &path,
            "Events",
            synthetic::schema(),
            Settings::new(Algorithm::Zstd, 1),
            1024,
            events.iter().cloned(),
        )
        .unwrap();
        let probe = TreeReader::open(&path).unwrap();
        let names = ["px", "nTrack"];
        let ids = ProjectionPlan::resolve_names(&probe.meta, &names).unwrap();
        let victim = probe.meta.baskets_for(ids[0])[1];
        let n = probe.meta.n_entries;
        // Flip bits in the basket's identity varint: deterministic damage
        // regardless of codec.
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[victim.file_offset as usize + 5] ^= 0x3F;
        std::fs::write(&path, bytes).unwrap();

        let par = ParallelTreeReader::open(&path, ReadAhead { workers: 2, depth: 2 }).unwrap();

        // Strict projection fails citing branch + offset; the latch then
        // repeats that context on the next call.
        let mut strict = par.project(&names).unwrap();
        let err = strict.read_columns().unwrap_err().to_string();
        assert!(err.contains("branch 'px'"), "{err}");
        assert!(err.contains(&format!("file offset {}", victim.file_offset)), "{err}");
        let latched = strict.read_columns().unwrap_err().to_string();
        assert!(latched.contains("projection already failed ("), "{latched}");
        assert!(latched.contains("px"), "{latched}");

        // Salvage batches: the victim's span drops out of the row stream
        // and is reported as a gap with absolute entry ids.
        let hole = victim.first_entry..victim.first_entry + victim.n_entries as u64;
        let mut proj = par.project_salvage(&names).unwrap();
        let mut seen = Vec::new();
        while let Some(batch) = proj.next_batch() {
            let batch = batch.unwrap();
            for (i, row) in batch.rows.iter().enumerate() {
                seen.push((batch.first_entry + i as u64, row.clone()));
            }
        }
        let expected: Vec<(u64, Vec<Value>)> = (0..n)
            .filter(|e| !hole.contains(e))
            .map(|e| {
                let ev = &events[e as usize];
                (e, vec![ev[ids[0] as usize].clone(), ev[ids[1] as usize].clone()])
            })
            .collect();
        assert_eq!(seen, expected);
        assert_eq!(
            proj.gaps(),
            &[GapSpan { first_entry: hole.start, n_entries: victim.n_entries as u64 }]
        );
        assert_eq!(proj.entries_skipped(), victim.n_entries as u64);
        let damage = proj.damage();
        assert_eq!(damage.len(), 1);
        assert_eq!(damage[0].branch, "px");
        let st = &proj.branch_stats()[0];
        assert_eq!((st.damaged_baskets, st.damaged_entries), (1, victim.n_entries as u64));
        assert_eq!(proj.branch_gaps(0), proj.gaps());
        assert!(proj.branch_gaps(1).is_empty());

        // Salvage columns (fresh reader): per-branch intact values, so the
        // damaged branch's column is shorter.
        let mut proj2 = par.project_salvage(&names).unwrap();
        let cols = proj2.read_columns().unwrap();
        assert_eq!(cols[0].len() as u64, n - victim.n_entries as u64);
        assert_eq!(cols[1].len() as u64, n);
        let intact: Vec<Value> = (0..n)
            .filter(|e| !hole.contains(e))
            .map(|e| events[e as usize][ids[0] as usize].clone())
            .collect();
        assert_eq!(cols[0], intact);

        // Mixing batch reads with a salvage column drain is rejected.
        let mut proj3 = par.project_salvage(&names).unwrap();
        proj3.set_max_batch_rows(5);
        let _ = proj3.next_batch().unwrap().unwrap();
        assert!(proj3.read_columns().is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn submission_order_delivers_identical_columns() {
        let path = write_sample("order", 350, 768);
        let par = ParallelTreeReader::open(&path, ReadAhead { workers: 2, depth: 2 }).unwrap();
        let ids = ProjectionPlan::resolve_names(&par.meta, &["py", "label", "nTrack"]).unwrap();
        let offset_plan = ProjectionPlan::new(&par.meta, &ids, PrefetchOrder::FileOffset).unwrap();
        let sub_plan = ProjectionPlan::new(&par.meta, &ids, PrefetchOrder::Submission).unwrap();
        let a = par.project_plan(&offset_plan).unwrap().read_columns().unwrap();
        let b = par.project_plan(&sub_plan).unwrap().read_columns().unwrap();
        assert_eq!(a, b);
        std::fs::remove_file(&path).ok();
    }
}
