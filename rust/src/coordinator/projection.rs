//! Columnar projection scans: read a *subset* of branches in **one pass**
//! over the file.
//!
//! "Optimizing ROOT IO For Analysis" (arXiv:1711.02659) observes that the
//! common analysis workload touches a small fraction of a tree's branches,
//! and that the dominant cost after decompression is the *seek pattern* of
//! per-branch reads. The PR-3 pipeline ([`super::read_pipeline`]) scans one
//! branch at a time: projecting k branches meant k independent sweeps over
//! the file, each skipping the other branches' baskets. This module
//! generalizes it to multi-branch jobs:
//!
//! ```text
//!  ProjectionPlan: merge k branches' BasketLoc lists, sort by file_offset
//!        │            (ONE monotonically-increasing read sweep)
//!        ▼
//!  BasketScan (PR-3 machinery: prefetch thread → N decode workers →
//!        │     in-submission-order delivery, pooled buffers)
//!        ▼
//!  ProjectionScan: reordering consumer keyed on (branch, basket seq) —
//!        │          routes interleaved baskets back to per-branch streams
//!        ▼
//!  ProjectionReader: per-branch event-order columns, or aligned row
//!                    batches via next_batch() (columns zipped per entry)
//! ```
//!
//! Plans can additionally be [sliced](ProjectionPlan::slice) to an **entry
//! range** `[first, last)` — the cluster-range read distributed and
//! partial-file workloads want (arXiv:1711.02659 §4): only the baskets
//! whose entry spans overlap the window are prefetched and decoded, and
//! the reader trims head/tail rows of boundary baskets so callers see
//! exactly the requested events. Entry spans come from the directory
//! ([`BasketLoc::entry_span`]); there is no wire-format change.
//!
//! Invariants (property-tested in `rust/tests/integration_projection.rs`
//! and `rust/tests/integration_entry_range.rs`):
//!  * a k-of-n projection is **byte-identical** to k independent serial
//!    [`TreeReader::read_branch`](crate::rfile::TreeReader::read_branch)
//!    calls, for any worker count and either prefetch order;
//!  * an entry-range projection is byte-identical to the full read
//!    followed by an in-memory slice — including empty windows, windows
//!    past EOF, and windows landing exactly on basket boundaries;
//!  * a corrupted basket in a projected branch fails the projection exactly
//!    like the serial reader — and does *not* fail projections that skip
//!    that branch (the columnar win: untouched branches are never read);
//!  * the [`PrefetchOrder::FileOffset`] plan issues one forward sweep:
//!    `ProjectionPlan::is_monotonic_sweep()` holds by construction (unit
//!    test below).

use crate::rfile::basket::BasketContent;
use crate::rfile::branch::{BranchType, Value};
use crate::rfile::meta::{BasketLoc, TreeMeta};
use crate::rfile::reader::decode_values;
use anyhow::{anyhow, bail, Result};
use std::collections::{BTreeMap, HashMap, VecDeque};

use super::read_pipeline::{BasketScan, ParallelTreeReader};

/// Order in which a projection's merged basket list is handed to the
/// prefetcher.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefetchOrder {
    /// Sort the merged list by `file_offset`: one monotonically-increasing
    /// sweep over the file (no backward seeks). The default.
    FileOffset,
    /// Branch-major concatenation in projection order — the PR-3 behaviour
    /// of running one branch after another. Kept as the bench baseline for
    /// the seek-pattern comparison.
    Submission,
}

/// A merged, ordered prefetch plan over the baskets of a set of projected
/// branches. Build with [`ProjectionPlan::new`] (branch ids) or let
/// [`ParallelTreeReader::project`] resolve names for you; narrow it to an
/// entry range with [`ProjectionPlan::slice`].
#[derive(Debug, Clone)]
pub struct ProjectionPlan {
    branch_ids: Vec<u32>,
    locs: Vec<BasketLoc>,
    order: PrefetchOrder,
    /// `[first, last)` entry window when the plan was sliced; `None` means
    /// the whole tree. Stored unclamped — readers clamp to the tree's
    /// entry count.
    entry_range: Option<(u64, u64)>,
}

impl ProjectionPlan {
    /// Merge the basket directories of `branch_ids` into one prefetch plan.
    /// Rejects empty projections, duplicate ids, and ids outside the tree's
    /// schema.
    pub fn new(meta: &TreeMeta, branch_ids: &[u32], order: PrefetchOrder) -> Result<Self> {
        if branch_ids.is_empty() {
            bail!("empty projection: no branches selected");
        }
        let n = meta.branches.len() as u32;
        let mut seen = vec![false; n as usize];
        for &id in branch_ids {
            if id >= n {
                bail!("projection references branch {id}, tree has {n} branches");
            }
            if seen[id as usize] {
                bail!("duplicate branch {id} ('{}') in projection", meta.branches[id as usize].name);
            }
            seen[id as usize] = true;
        }
        // Branch-major merge first (each per-branch list is already ordered
        // by basket_index), then the offset sort if requested. The sort is
        // stable, so equal offsets (impossible in well-formed files, but
        // cheap to be deterministic about) keep submission order.
        let mut locs = meta.baskets_for_branches(branch_ids);
        if order == PrefetchOrder::FileOffset {
            locs.sort_by_key(|l| l.file_offset);
        }
        Ok(Self { branch_ids: branch_ids.to_vec(), locs, order, entry_range: None })
    }

    /// Narrow the plan to the baskets whose entry spans overlap
    /// `[first, last)` — the cluster-range trim for partial-file reads.
    /// Spans come from the directory's `first_entry`/`n_entries`
    /// ([`BasketLoc::entry_span`]), so no extra I/O happens here. Prefetch
    /// order is preserved (slicing an offset-sorted plan keeps it one
    /// forward sweep). Slicing an already-sliced plan intersects the
    /// ranges. A backwards or fully out-of-range window yields an empty
    /// plan, which reads zero baskets and zero entries.
    pub fn slice(&self, first: u64, last: u64) -> Self {
        let (first, last) = match self.entry_range {
            None => (first, last.max(first)),
            Some((a, b)) => {
                let lo = first.max(a);
                (lo, last.min(b).max(lo))
            }
        };
        let locs = self.locs.iter().copied().filter(|l| l.overlaps(first, last)).collect();
        Self {
            branch_ids: self.branch_ids.clone(),
            locs,
            order: self.order,
            entry_range: Some((first, last)),
        }
    }

    /// The `[first, last)` entry window this plan was sliced to, if any.
    pub fn entry_range(&self) -> Option<(u64, u64)> {
        self.entry_range
    }

    /// Resolve branch *names* to ids against `meta` (first error wins).
    pub fn resolve_names(meta: &TreeMeta, names: &[&str]) -> Result<Vec<u32>> {
        names
            .iter()
            .map(|name| {
                meta.branch_id(name)
                    .ok_or_else(|| anyhow!("no branch '{name}' in tree '{}'", meta.name))
            })
            .collect()
    }

    /// Plan covering the *first* basket of every branch, offset-sorted —
    /// the file-profiling sweep [`crate::runtime::analyze_tree`] rides
    /// (one forward pass instead of a branch-major walk).
    pub fn first_baskets(meta: &TreeMeta) -> Self {
        let mut firsts = meta.first_baskets();
        firsts.sort_by_key(|l| l.file_offset);
        let branch_ids = (0..meta.branches.len() as u32).collect();
        Self { branch_ids, locs: firsts, order: PrefetchOrder::FileOffset, entry_range: None }
    }

    /// The merged basket list in prefetch order.
    pub fn locs(&self) -> &[BasketLoc] {
        &self.locs
    }

    /// Projected branch ids in projection (slot) order.
    pub fn branch_ids(&self) -> &[u32] {
        &self.branch_ids
    }

    pub fn order(&self) -> PrefetchOrder {
        self.order
    }

    /// True iff the plan's file offsets never decrease — the prefetcher
    /// issues one forward sweep over the file. Holds by construction for
    /// [`PrefetchOrder::FileOffset`].
    pub fn is_monotonic_sweep(&self) -> bool {
        self.locs.windows(2).all(|w| w[0].file_offset <= w[1].file_offset)
    }

    /// Number of backward seeks the prefetcher would issue (positions where
    /// the next basket sits at a lower offset than the previous one).
    pub fn backward_seeks(&self) -> usize {
        self.locs.windows(2).filter(|w| w[1].file_offset < w[0].file_offset).count()
    }

    /// Total uncompressed bytes the plan covers (throughput denominator).
    pub fn logical_bytes(&self) -> u64 {
        self.locs.iter().map(|l| l.uncompressed_len as u64).sum()
    }

    /// Total compressed bytes the plan reads off the file.
    pub fn compressed_bytes(&self) -> u64 {
        self.locs.iter().map(|l| l.compressed_len as u64).sum()
    }
}

/// Per-slot reorder state: baskets of one projected branch.
struct SlotState {
    branch_id: u32,
    /// Next basket_index to deliver for this branch.
    next_index: u32,
    /// Baskets that arrived ahead of their predecessor (keyed on
    /// basket_index). Empty in steady state for both standard plan orders —
    /// a branch's baskets sit at increasing offsets, so both sorts preserve
    /// each per-branch subsequence — but the reorder keeps delivery correct
    /// for *any* plan permutation.
    parked: BTreeMap<u32, (BasketLoc, BasketContent)>,
}

/// Multi-branch scan: wraps the PR-3 [`BasketScan`] and re-routes its
/// interleaved delivery into per-branch streams, each in basket_index
/// (= event) order. Yields `(slot, BasketLoc, BasketContent)` where `slot`
/// indexes the projection's branch list.
pub struct ProjectionScan {
    scan: BasketScan,
    slots: Vec<SlotState>,
    slot_of: HashMap<u32, usize>,
    /// Baskets unblocked by the last arrival, not yet handed out.
    ready: VecDeque<(usize, BasketLoc, BasketContent)>,
    /// Set after a terminal error so the stream ends instead of re-erroring.
    failed: bool,
}

impl ProjectionScan {
    fn new(scan: BasketScan, plan: &ProjectionPlan) -> Self {
        // A sliced plan starts each branch mid-directory: the first
        // deliverable basket_index per branch is the smallest one in the
        // plan, not 0.
        let mut first_index: HashMap<u32, u32> = HashMap::new();
        for l in plan.locs() {
            let e = first_index.entry(l.branch_id).or_insert(l.basket_index);
            *e = (*e).min(l.basket_index);
        }
        let branch_ids = plan.branch_ids();
        let slots: Vec<SlotState> = branch_ids
            .iter()
            .map(|&id| SlotState {
                branch_id: id,
                next_index: first_index.get(&id).copied().unwrap_or(0),
                parked: BTreeMap::new(),
            })
            .collect();
        let slot_of = branch_ids.iter().enumerate().map(|(i, &id)| (id, i)).collect();
        Self { scan, slots, slot_of, ready: VecDeque::new(), failed: false }
    }

    /// Next basket in per-branch order (see type docs), or `None` when the
    /// plan is exhausted. Decode errors surface on the basket that failed,
    /// exactly like [`BasketScan::next_basket`].
    pub fn next_basket(&mut self) -> Option<Result<(usize, BasketLoc, BasketContent)>> {
        if self.failed {
            return None;
        }
        loop {
            if let Some(item) = self.ready.pop_front() {
                return Some(Ok(item));
            }
            match self.scan.next_basket() {
                None => {
                    if self.slots.iter().any(|s| !s.parked.is_empty()) {
                        self.failed = true;
                        return Some(Err(anyhow!(
                            "projection scan ended with undeliverable parked baskets \
                             (directory has non-contiguous basket indices)"
                        )));
                    }
                    return None;
                }
                Some(Err(e)) => {
                    self.failed = true;
                    return Some(Err(e));
                }
                Some(Ok((loc, content))) => {
                    let Some(&slot) = self.slot_of.get(&loc.branch_id) else {
                        self.failed = true;
                        return Some(Err(anyhow!(
                            "scan delivered basket for unprojected branch {}",
                            loc.branch_id
                        )));
                    };
                    let (branch_id, basket_index) = (loc.branch_id, loc.basket_index);
                    let st = &mut self.slots[slot];
                    let duplicate = match basket_index.cmp(&st.next_index) {
                        std::cmp::Ordering::Less => true,
                        std::cmp::Ordering::Equal => {
                            st.next_index += 1;
                            self.ready.push_back((slot, loc, content));
                            // Parked successors become deliverable in order.
                            while let Some((l, c)) = st.parked.remove(&st.next_index) {
                                st.next_index += 1;
                                self.ready.push_back((slot, l, c));
                            }
                            false
                        }
                        std::cmp::Ordering::Greater => {
                            st.parked.insert(basket_index, (loc, content)).is_some()
                        }
                    };
                    if duplicate {
                        self.failed = true;
                        return Some(Err(anyhow!(
                            "duplicate basket ({branch_id},{basket_index}) in projection plan"
                        )));
                    }
                }
            }
        }
    }

    /// Return a consumed basket's buffers to the underlying scan's pools
    /// (see [`BasketScan::recycle`]).
    pub fn recycle(&self, content: BasketContent) {
        self.scan.recycle(content);
    }

    /// Branch id behind a delivery slot.
    pub fn branch_id(&self, slot: usize) -> u32 {
        self.slots[slot].branch_id
    }
}

/// Read statistics for one projected branch (CLI `--branches` table).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BranchReadStats {
    pub branch_id: u32,
    pub name: String,
    pub baskets: u64,
    pub entries: u64,
    pub compressed_bytes: u64,
    pub logical_bytes: u64,
}

/// An aligned batch of projected rows: `rows[i][slot]` is the value of the
/// projection's `slot`-th branch at entry `first_entry + i`.
#[derive(Debug, Clone, PartialEq)]
pub struct RowBatch {
    pub first_entry: u64,
    pub rows: Vec<Vec<Value>>,
}

impl RowBatch {
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

/// Event-order consumer over a [`ProjectionScan`]: buffers each branch's
/// decoded values and zips them into aligned [`RowBatch`]es
/// ([`ProjectionReader::next_batch`]) or whole per-branch columns
/// ([`ProjectionReader::read_columns`]).
///
/// ```
/// use rootio::compression::{Algorithm, Settings};
/// use rootio::coordinator::{ParallelTreeReader, ReadAhead};
/// use rootio::gen::synthetic;
/// use rootio::rfile::write_tree_serial;
///
/// let path = std::env::temp_dir().join(format!("rootio_doc_proj_{}.rfil", std::process::id()));
/// let events = synthetic::events(300, 11);
/// write_tree_serial(&path, "Events", synthetic::schema(),
///                   Settings::new(Algorithm::Lz4, 1), 2048, events.iter().cloned()).unwrap();
///
/// let reader = ParallelTreeReader::open(&path, ReadAhead::with_workers(2)).unwrap();
/// // Project 2 of the 12 branches: one pass over the file, other branches
/// // are never read or decompressed.
/// let mut proj = reader.project(&["px", "nTrack"]).unwrap();
/// let mut rows = 0usize;
/// while let Some(batch) = proj.next_batch() {
///     let batch = batch.unwrap();
///     assert!(batch.rows.iter().all(|row| row.len() == 2));
///     rows += batch.len();
/// }
/// assert_eq!(rows, 300);
/// std::fs::remove_file(&path).ok();
/// ```
pub struct ProjectionReader {
    scan: ProjectionScan,
    types: Vec<BranchType>,
    stats: Vec<BranchReadStats>,
    /// First entry of the projected window (0 for whole-tree projections).
    start: u64,
    /// One past the last entry of the window (tree entry count when whole).
    end: u64,
    /// Entries this projection emits: `end - start`.
    n_entries: u64,
    /// Decoded-but-unemitted values per slot (front = oldest entry).
    bufs: Vec<VecDeque<Value>>,
    value_scratch: Vec<Value>,
    emitted: u64,
    max_batch_rows: Option<usize>,
    /// Latched after any error: a failed basket's values never reached
    /// `bufs`, so continuing would emit misaligned rows. The stream ends
    /// instead.
    failed: bool,
}

impl ProjectionReader {
    fn new(scan: ProjectionScan, meta: &TreeMeta, plan: &ProjectionPlan) -> Self {
        let branch_ids = plan.branch_ids();
        let types = branch_ids.iter().map(|&id| meta.branches[id as usize].ty).collect();
        let stats = branch_ids
            .iter()
            .map(|&id| BranchReadStats {
                branch_id: id,
                name: meta.branches[id as usize].name.clone(),
                ..BranchReadStats::default()
            })
            .collect();
        let bufs = branch_ids.iter().map(|_| VecDeque::new()).collect();
        let (start, end) = match plan.entry_range() {
            None => (0, meta.n_entries),
            Some((a, b)) => meta.clamp_entry_range(a, b),
        };
        Self {
            scan,
            types,
            stats,
            start,
            end,
            n_entries: end - start,
            bufs,
            value_scratch: Vec::new(),
            emitted: 0,
            max_batch_rows: None,
            failed: false,
        }
    }

    /// Cap the row count of each [`RowBatch`] (default: uncapped — batch
    /// boundaries fall wherever basket alignment puts them).
    pub fn set_max_batch_rows(&mut self, rows: usize) {
        self.max_batch_rows = if rows == 0 { None } else { Some(rows) };
    }

    /// Per-branch read statistics accumulated so far (complete once the
    /// projection is drained).
    pub fn branch_stats(&self) -> &[BranchReadStats] {
        &self.stats
    }

    /// Entries emitted through [`ProjectionReader::next_batch`] so far.
    pub fn entries_emitted(&self) -> u64 {
        self.emitted
    }

    /// The absolute entry window `[first, last)` this projection covers —
    /// the whole tree unless the plan was sliced, already clamped to the
    /// tree's entry count.
    pub fn entry_range(&self) -> (u64, u64) {
        (self.start, self.end)
    }

    fn note_basket(&mut self, slot: usize, loc: &BasketLoc, content: &BasketContent) {
        let st = &mut self.stats[slot];
        st.baskets += 1;
        st.entries += content.n_entries as u64;
        st.compressed_bytes += loc.compressed_len as u64;
        st.logical_bytes += (content.data.len() + 4 * content.offsets.len()) as u64;
    }

    /// Pull baskets until every projected branch has at least one pending
    /// value, then emit the aligned rows. `None` once all entries are out.
    /// An error is terminal: the failed basket's values never reached the
    /// column buffers, so the stream ends (further calls return `None`)
    /// rather than emitting misaligned rows.
    pub fn next_batch(&mut self) -> Option<Result<RowBatch>> {
        if self.failed || self.emitted >= self.n_entries {
            return None;
        }
        loop {
            let avail = self.bufs.iter().map(|b| b.len()).min().unwrap_or(0);
            if avail > 0 {
                return Some(Ok(self.emit_rows(avail)));
            }
            match self.scan.next_basket() {
                Some(Ok((slot, loc, content))) => {
                    self.value_scratch.clear();
                    if let Err(e) = decode_values(&content, self.types[slot], &mut self.value_scratch)
                    {
                        self.failed = true;
                        return Some(Err(e));
                    }
                    self.note_basket(slot, &loc, &content);
                    self.scan.recycle(content);
                    // Boundary baskets of a sliced projection decode whole
                    // but contribute only the rows inside the window.
                    let (from, to) = loc.trim_bounds(self.start, self.end);
                    self.bufs[slot].extend(self.value_scratch.drain(..to).skip(from));
                }
                Some(Err(e)) => {
                    self.failed = true;
                    return Some(Err(e));
                }
                None => {
                    self.failed = true;
                    return Some(Err(anyhow!(
                        "projection scan ended after {} of {} entries",
                        self.emitted,
                        self.n_entries
                    )));
                }
            }
        }
    }

    fn emit_rows(&mut self, mut avail: usize) -> RowBatch {
        if let Some(cap) = self.max_batch_rows {
            avail = avail.min(cap);
        }
        // Absolute entry id: offset by the window start for sliced reads.
        let first_entry = self.start + self.emitted;
        let k = self.bufs.len();
        let mut rows: Vec<Vec<Value>> = (0..avail).map(|_| Vec::with_capacity(k)).collect();
        for buf in self.bufs.iter_mut() {
            for row in rows.iter_mut() {
                row.push(buf.pop_front().expect("avail is min over buffer lengths"));
            }
        }
        self.emitted += avail as u64;
        RowBatch { first_entry, rows }
    }

    /// Drain the projection into whole per-branch columns (event order, one
    /// `Vec<Value>` per projected branch, in projection order). Covers the
    /// window entries not yet emitted through
    /// [`ProjectionReader::next_batch`]; verifies every column reaches the
    /// projection window's entry count (the whole tree unless the plan was
    /// sliced). Errors are terminal, like
    /// [`ProjectionReader::next_batch`]'s.
    pub fn read_columns(&mut self) -> Result<Vec<Vec<Value>>> {
        if self.failed {
            bail!("projection already failed; open a new projection to retry");
        }
        let r = self.read_columns_inner();
        if r.is_err() {
            self.failed = true;
        }
        r
    }

    fn read_columns_inner(&mut self) -> Result<Vec<Vec<Value>>> {
        let expect = self.n_entries - self.emitted;
        let mut columns: Vec<Vec<Value>> = self
            .bufs
            .iter_mut()
            .map(|b| {
                let mut col = Vec::with_capacity(expect as usize);
                col.extend(b.drain(..));
                col
            })
            .collect();
        while let Some(item) = self.scan.next_basket() {
            let (slot, loc, content) = item?;
            self.note_basket(slot, &loc, &content);
            let (from, to) = loc.trim_bounds(self.start, self.end);
            if from == 0 && to == loc.n_entries as usize {
                // Interior basket: decode straight into the column.
                decode_values(&content, self.types[slot], &mut columns[slot])?;
            } else {
                // Boundary basket of a sliced window: decode whole, keep
                // only the rows inside `[start, end)`.
                self.value_scratch.clear();
                decode_values(&content, self.types[slot], &mut self.value_scratch)?;
                columns[slot].extend(self.value_scratch.drain(..to).skip(from));
            }
            self.scan.recycle(content);
        }
        for (slot, col) in columns.iter().enumerate() {
            if col.len() as u64 != expect {
                bail!(
                    "branch {} ('{}'): {} entries decoded, expected {expect}",
                    self.stats[slot].branch_id,
                    self.stats[slot].name,
                    col.len()
                );
            }
        }
        self.emitted = self.n_entries;
        Ok(columns)
    }
}

impl ParallelTreeReader {
    /// Project `branches` (by name) through one offset-sorted pipelined
    /// pass — see [`ProjectionReader`]. The scan starts immediately.
    pub fn project(&self, branches: &[&str]) -> Result<ProjectionReader> {
        let ids = ProjectionPlan::resolve_names(&self.meta, branches)?;
        let plan = ProjectionPlan::new(&self.meta, &ids, PrefetchOrder::FileOffset)?;
        self.project_plan(&plan)
    }

    /// Project `branches` over the entry window `[range.start, range.end)`
    /// only: the plan is [sliced](ProjectionPlan::slice) to the baskets
    /// overlapping the window, the pipeline decodes only those, and the
    /// reader trims head/tail rows of boundary baskets so callers see
    /// exactly the requested entries. Ranges are clamped to the tree
    /// (past-EOF and empty windows yield zero rows, not errors).
    pub fn project_range(
        &self,
        branches: &[&str],
        range: std::ops::Range<u64>,
    ) -> Result<ProjectionReader> {
        let ids = ProjectionPlan::resolve_names(&self.meta, branches)?;
        let plan = ProjectionPlan::new(&self.meta, &ids, PrefetchOrder::FileOffset)?
            .slice(range.start, range.end);
        self.project_plan(&plan)
    }

    /// Project with an explicit, pre-built [`ProjectionPlan`] (choose the
    /// prefetch order, slice an entry range, inspect the sweep, reuse a
    /// plan across readers).
    pub fn project_plan(&self, plan: &ProjectionPlan) -> Result<ProjectionReader> {
        let scan = self.scan(plan.locs().to_vec())?;
        Ok(ProjectionReader::new(ProjectionScan::new(scan, plan), &self.meta, plan))
    }

    /// One-call multi-branch read: per-branch event-order columns for
    /// `branches`, byte-identical to k independent
    /// [`TreeReader::read_branch`](crate::rfile::TreeReader::read_branch)
    /// calls but issued as a single offset-sorted sweep.
    pub fn read_branches(&self, branches: &[&str]) -> Result<Vec<Vec<Value>>> {
        self.project(branches)?.read_columns()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::{Algorithm, Settings};
    use crate::coordinator::ReadAhead;
    use crate::gen::synthetic;
    use crate::rfile::{write_tree_serial, TreeReader};
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("rootio_proj_{}_{}", std::process::id(), name));
        p
    }

    fn write_sample(name: &str, n: usize, basket: usize) -> PathBuf {
        let path = tmp(name);
        let events = synthetic::events(n, 0x13AF);
        write_tree_serial(
            &path,
            "Events",
            synthetic::schema(),
            Settings::new(Algorithm::Lz4, 1),
            basket,
            events.iter().cloned(),
        )
        .unwrap();
        path
    }

    #[test]
    fn offset_sorted_plan_is_one_monotonic_sweep() {
        let path = write_sample("plan", 400, 1024);
        let reader = TreeReader::open(&path).unwrap();
        let ids: Vec<u32> = vec![0, 3, 7, 8];
        let plan = ProjectionPlan::new(&reader.meta, &ids, PrefetchOrder::FileOffset).unwrap();
        assert!(plan.is_monotonic_sweep(), "offset-sorted plan must never seek backward");
        assert_eq!(plan.backward_seeks(), 0);
        assert_eq!(
            plan.locs().len(),
            ids.iter().map(|&b| reader.meta.baskets_for(b).len()).sum::<usize>()
        );

        // The branch-major submission plan re-sweeps the file once per
        // branch: with multiple interleaved baskets per branch it must seek
        // backward at least once per branch boundary.
        let sub = ProjectionPlan::new(&reader.meta, &ids, PrefetchOrder::Submission).unwrap();
        assert!(!sub.is_monotonic_sweep());
        assert!(sub.backward_seeks() >= ids.len() - 1, "seeks: {}", sub.backward_seeks());
        assert_eq!(plan.logical_bytes(), sub.logical_bytes());

        // First-basket profiling plan: also one forward sweep.
        assert!(ProjectionPlan::first_baskets(&reader.meta).is_monotonic_sweep());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn plan_rejects_bad_projections() {
        let path = write_sample("plan_bad", 50, 4096);
        let reader = TreeReader::open(&path).unwrap();
        assert!(ProjectionPlan::new(&reader.meta, &[], PrefetchOrder::FileOffset).is_err());
        assert!(ProjectionPlan::new(&reader.meta, &[0, 0], PrefetchOrder::FileOffset).is_err());
        assert!(ProjectionPlan::new(&reader.meta, &[99], PrefetchOrder::FileOffset).is_err());
        assert!(ProjectionPlan::resolve_names(&reader.meta, &["nope"]).is_err());
        assert_eq!(ProjectionPlan::resolve_names(&reader.meta, &["px", "nTrack"]).unwrap(), vec![3, 6]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn projection_columns_match_serial_and_stats_add_up() {
        let path = write_sample("cols", 500, 1024);
        let mut serial = TreeReader::open(&path).unwrap();
        let par = ParallelTreeReader::open(&path, ReadAhead { workers: 2, depth: 3 }).unwrap();
        let names = ["Track_pt", "px", "is_good"];
        let mut proj = par.project(&names).unwrap();
        let columns = proj.read_columns().unwrap();
        assert_eq!(columns.len(), names.len());
        for (slot, name) in names.iter().enumerate() {
            let id = serial.branch_id(name).unwrap();
            assert_eq!(columns[slot], serial.read_branch(id).unwrap(), "branch {name}");
            let st = &proj.branch_stats()[slot];
            assert_eq!(st.name, *name);
            assert_eq!(st.baskets, serial.baskets_for(id).len() as u64);
            assert_eq!(st.entries, serial.meta.n_entries);
            assert_eq!(
                st.compressed_bytes,
                serial.baskets_for(id).iter().map(|l| l.compressed_len as u64).sum::<u64>()
            );
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn batches_zip_columns_in_entry_order() {
        let path = write_sample("batch", 300, 512);
        let mut serial = TreeReader::open(&path).unwrap();
        let par = ParallelTreeReader::open(&path, ReadAhead { workers: 3, depth: 2 }).unwrap();
        let names = ["event_id", "Track_charge"];
        let cols: Vec<Vec<Value>> = names
            .iter()
            .map(|n| serial.read_branch(serial.branch_id(n).unwrap()).unwrap())
            .collect();
        let mut proj = par.project(&names).unwrap();
        proj.set_max_batch_rows(37); // force uneven batch boundaries
        let mut entry = 0u64;
        while let Some(batch) = proj.next_batch() {
            let batch = batch.unwrap();
            assert_eq!(batch.first_entry, entry);
            assert!(batch.len() <= 37);
            assert!(!batch.is_empty());
            for (i, row) in batch.rows.iter().enumerate() {
                let e = (entry + i as u64) as usize;
                assert_eq!(row.len(), names.len());
                for (slot, v) in row.iter().enumerate() {
                    assert_eq!(*v, cols[slot][e], "entry {e} slot {slot}");
                }
            }
            entry += batch.len() as u64;
        }
        assert_eq!(entry, serial.meta.n_entries);
        assert_eq!(proj.entries_emitted(), entry);
        // Exhausted: further calls keep returning None.
        assert!(proj.next_batch().is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sliced_plans_keep_only_overlapping_baskets() {
        let path = write_sample("slice_plan", 400, 1024);
        let reader = TreeReader::open(&path).unwrap();
        let ids = ProjectionPlan::resolve_names(&reader.meta, &["px", "Track_pt"]).unwrap();
        let plan = ProjectionPlan::new(&reader.meta, &ids, PrefetchOrder::FileOffset).unwrap();
        let n = reader.meta.n_entries;
        let sliced = plan.slice(n / 4, 3 * n / 4);
        assert!(sliced.locs().iter().all(|l| l.overlaps(n / 4, 3 * n / 4)));
        assert!(sliced.locs().len() < plan.locs().len());
        assert!(sliced.is_monotonic_sweep(), "slicing must preserve the forward sweep");
        assert_eq!(sliced.entry_range(), Some((n / 4, 3 * n / 4)));
        // Every in-range basket of each projected branch is present.
        for &id in &ids {
            assert_eq!(
                sliced.locs().iter().filter(|l| l.branch_id == id).count(),
                reader.meta.baskets_for_range(id, n / 4, 3 * n / 4).len(),
                "branch {id}"
            );
        }
        // Slicing a slice intersects the windows.
        let nested = sliced.slice(0, n / 2);
        assert_eq!(nested.entry_range(), Some((n / 4, n / 2)));
        assert!(nested.locs().iter().all(|l| l.overlaps(n / 4, n / 2)));
        // Empty and out-of-range windows yield empty plans.
        assert!(plan.slice(10, 10).locs().is_empty());
        assert!(plan.slice(n + 5, n + 50).locs().is_empty());
        assert!(plan.slice(30, 10).locs().is_empty(), "backwards window is empty");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn project_range_matches_in_memory_slice() {
        let path = write_sample("range_cols", 500, 1024);
        let mut serial = TreeReader::open(&path).unwrap();
        let par = ParallelTreeReader::open(&path, ReadAhead { workers: 2, depth: 3 }).unwrap();
        let names = ["event_id", "Track_pt"];
        let full: Vec<Vec<Value>> = names
            .iter()
            .map(|n| serial.read_branch(serial.branch_id(n).unwrap()).unwrap())
            .collect();
        let n = serial.meta.n_entries;
        for (a, b) in [(0, n), (n / 3, 2 * n / 3), (0, 1), (n - 1, n), (7, 7), (n, n + 9)] {
            let mut proj = par.project_range(&names, a..b).unwrap();
            let cols = proj.read_columns().unwrap();
            let (ca, cb) = (a.min(n) as usize, b.min(n).max(a.min(n)) as usize);
            for (slot, col) in cols.iter().enumerate() {
                assert_eq!(col.as_slice(), &full[slot][ca..cb], "range [{a},{b}) slot {slot}");
            }
            assert_eq!(proj.entry_range(), (ca as u64, cb as u64));
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn ranged_batches_report_absolute_entries() {
        let path = write_sample("range_batch", 300, 512);
        let mut serial = TreeReader::open(&path).unwrap();
        let par = ParallelTreeReader::open(&path, ReadAhead { workers: 2, depth: 2 }).unwrap();
        let names = ["py", "label"];
        let cols: Vec<Vec<Value>> = names
            .iter()
            .map(|n| serial.read_branch(serial.branch_id(n).unwrap()).unwrap())
            .collect();
        let (a, b) = (41u64, 227u64);
        let mut proj = par.project_range(&names, a..b).unwrap();
        proj.set_max_batch_rows(23);
        let mut entry = a;
        while let Some(batch) = proj.next_batch() {
            let batch = batch.unwrap();
            assert_eq!(batch.first_entry, entry, "batches carry absolute entry ids");
            for (i, row) in batch.rows.iter().enumerate() {
                let e = (entry + i as u64) as usize;
                for (slot, v) in row.iter().enumerate() {
                    assert_eq!(*v, cols[slot][e], "entry {e} slot {slot}");
                }
            }
            entry += batch.len() as u64;
        }
        assert_eq!(entry, b);
        assert_eq!(proj.entries_emitted(), b - a);
        assert!(proj.next_batch().is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn submission_order_delivers_identical_columns() {
        let path = write_sample("order", 350, 768);
        let par = ParallelTreeReader::open(&path, ReadAhead { workers: 2, depth: 2 }).unwrap();
        let ids = ProjectionPlan::resolve_names(&par.meta, &["py", "label", "nTrack"]).unwrap();
        let offset_plan = ProjectionPlan::new(&par.meta, &ids, PrefetchOrder::FileOffset).unwrap();
        let sub_plan = ProjectionPlan::new(&par.meta, &ids, PrefetchOrder::Submission).unwrap();
        let a = par.project_plan(&offset_plan).unwrap().read_columns().unwrap();
        let b = par.project_plan(&sub_plan).unwrap().read_columns().unwrap();
        assert_eq!(a, b);
        std::fs::remove_file(&path).ok();
    }
}
