//! `rootio repack` — profile-driven file rewriting, the **act** step that
//! closes the adaptive loop (observe: [`crate::runtime::ReadFeedback`];
//! advise: `rootio inspect --replan profile`; act: here).
//!
//! The paper's thesis is matching compression to use case; "Optimizing
//! ROOT IO For Analysis" and "ROOT I/O compression improvements for HEP
//! analysis" (PAPERS.md) both show that re-matching codecs to *observed*
//! access patterns and re-chunking basket/cluster sizes are the largest
//! levers on read throughput and disk footprint. [`repack_file`] applies
//! both retroactively to an existing RFIL file:
//!
//! ```text
//!  source.rfil ──ParallelTreeReader::scan──▶ decoded baskets (branch-major)
//!       │                                          │
//!       │  runtime::analyze_tree (features)        ▼ per-branch Rechunker:
//!       │  ReadFeedback (intensity, window)   re-split entries toward the
//!       ▼                                     planned basket target, rebase
//!  Planner::plan_repack per branch            jagged offsets
//!  (codec + precond + entropy + basket size)       │
//!                                                  ▼
//!  repacked.rfil ◀──ParallelSink (parallel compress, ordered commit)──┘
//!                  + one trained dictionary record for small-basket data
//! ```
//!
//! Guarantees (property-tested in `rust/tests/integration_repack.rs`):
//!
//! * **Exact oracle** — the output is event-for-event identical to the
//!   source under `read_all_events` / `read_all_events_range`, whatever
//!   the profile says; repack only moves basket boundaries and codec
//!   settings, never data.
//! * **Directory invariants** — per-branch entry spans stay contiguous
//!   from 0 and the rewritten directory is sorted by
//!   `(branch_id, basket_index)`; baskets are committed branch-major in
//!   file order, so an offset-sorted projection plan over the output is a
//!   monotonic sweep.
//! * **Version normalization** — the writer stamps the current container
//!   version, so repacking any accepted input (v2 or v3) emits a v3 file.
//! * **Honest failure** — a damaged input fails the rewrite by default;
//!   with [`RepackOptions::salvage`] the intact rows are rewritten and
//!   every dropped entry span is reported in the
//!   [`RepackReport::gaps`] (rows are dropped across *all* branches so
//!   the output stays rectangular).
//!
//! ```
//! use rootio::compression::{Algorithm, Settings};
//! use rootio::coordinator::repack::{repack_file, RepackOptions};
//! use rootio::gen::synthetic;
//! use rootio::rfile::{write_tree_serial, TreeReader};
//!
//! let dir = std::env::temp_dir();
//! let src = dir.join(format!("rootio_doc_repack_src_{}.rfil", std::process::id()));
//! let dst = dir.join(format!("rootio_doc_repack_dst_{}.rfil", std::process::id()));
//! let events = synthetic::events(300, 9);
//! write_tree_serial(&src, "Events", synthetic::schema(),
//!                   Settings::new(Algorithm::Zlib, 6), 2048, events.iter().cloned()).unwrap();
//!
//! // Rewrite with per-branch planned settings and re-chunked baskets …
//! let report = repack_file(&src, &dst, &RepackOptions::default()).unwrap();
//! assert_eq!(report.n_entries_out, 300);
//!
//! // … and the repacked file is event-for-event identical.
//! let mut out = TreeReader::open(&dst).unwrap();
//! assert_eq!(out.read_all_events().unwrap(), events);
//! std::fs::remove_file(&src).ok();
//! std::fs::remove_file(&dst).ok();
//! ```

use crate::compression::Settings;
use crate::coordinator::adaptive::{FeatureSource, Planner, RepackDecision, UseCase};
use crate::coordinator::pipeline::{ParallelSink, PipelineConfig};
use crate::coordinator::read_pipeline::{DamageRecord, ParallelTreeReader, ReadAhead, ScanMode};
use crate::rfile::basket::{BasketContent, PendingBasket};
use crate::rfile::branch::{BranchDef, BranchType};
use crate::rfile::format::RecordKind;
use crate::rfile::meta::{push_gap, GapSpan, TreeMeta};
use crate::rfile::writer::{BasketSink, RecordWriter};
use crate::runtime::analyzer::BUCKETS;
use crate::runtime::{analyze_tree, ReadFeedback};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Default budget (bytes) for the trained shared dictionary covering
/// small-basket branches. Matches the analyzer's smallest bucket: a
/// dictionary larger than the baskets it seeds is wasted.
pub const DEFAULT_DICT_BUDGET: usize = 4 * 1024;

/// Sample baskets taken per dictionary-eligible branch for training.
const DICT_SAMPLES_PER_BRANCH: usize = 4;

/// How a repack run is steered. `Default` repacks without a profile under
/// the `Balanced` use case with automatic basket targets and dictionary
/// training on.
#[derive(Debug, Clone)]
pub struct RepackOptions {
    /// Static use case applied to every branch when no profile is given
    /// (with a profile, per-branch intensity overrides this).
    pub use_case: UseCase,
    /// Recorded access profile; when present, per-branch settings and
    /// basket targets follow observed intensity and window sizes.
    pub profile: Option<ReadFeedback>,
    /// Force one basket target (bytes) for every branch
    /// (`--target-basket-kb`); `None` lets the planner derive per-branch
    /// targets.
    pub target_basket_bytes: Option<usize>,
    /// Reader/writer worker threads (0 = automatic).
    pub workers: usize,
    /// Rewrite the intact complement of a damaged file instead of
    /// failing; dropped rows are reported as [`RepackReport::gaps`].
    pub salvage: bool,
    /// Trained-dictionary budget in bytes (0 disables training).
    pub dict_budget: usize,
}

impl Default for RepackOptions {
    fn default() -> Self {
        Self {
            use_case: UseCase::Balanced,
            profile: None,
            target_basket_bytes: None,
            workers: 0,
            salvage: false,
            dict_budget: DEFAULT_DICT_BUDGET,
        }
    }
}

/// One branch's resolved repack plan, as applied to the output file.
#[derive(Debug, Clone)]
pub struct BranchPlan {
    pub branch_id: u32,
    pub name: String,
    /// Observed per-scan read intensity (`None` when repacking without a
    /// profile).
    pub intensity: Option<f64>,
    /// Effective use case + settings + basket target from
    /// [`Planner::plan_repack`].
    pub decision: RepackDecision,
    /// Whether this branch's baskets fed the trained dictionary
    /// (small-basket branches only).
    pub dict_sampled: bool,
}

/// What a [`repack_file`] run did: the per-branch plans it applied and
/// the before/after accounting for the operations book's size table.
#[derive(Debug, Clone)]
pub struct RepackReport {
    pub plans: Vec<BranchPlan>,
    /// Entries in the source tree.
    pub n_entries_in: u64,
    /// Entries in the output tree (less than `n_entries_in` only under
    /// salvage with damage).
    pub n_entries_out: u64,
    pub baskets_in: usize,
    pub baskets_out: usize,
    /// Source file size in bytes.
    pub bytes_in: u64,
    /// Output file size in bytes.
    pub bytes_out: u64,
    /// Trained dictionary size (0 = no dictionary record written).
    pub dictionary_bytes: usize,
    /// Entry spans dropped from every branch (salvage mode; empty on a
    /// clean repack). Sorted and merged.
    pub gaps: Vec<GapSpan>,
    /// Per-basket damage reports from the salvage read.
    pub damage: Vec<DamageRecord>,
}

impl RepackReport {
    /// Human-readable summary (the `rootio repack` CLI output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let pct = if self.bytes_in > 0 {
            100.0 * self.bytes_out as f64 / self.bytes_in as f64
        } else {
            0.0
        };
        out.push_str(&format!(
            "repacked {} entries ({} in), {} -> {} baskets, {} -> {} bytes ({:.1}% of source)\n",
            self.n_entries_out,
            self.n_entries_in,
            self.baskets_in,
            self.baskets_out,
            self.bytes_in,
            self.bytes_out,
            pct
        ));
        if self.dictionary_bytes > 0 {
            let n = self.plans.iter().filter(|p| p.dict_sampled).count();
            out.push_str(&format!(
                "dictionary: {} bytes trained from {} small-basket branch(es)\n",
                self.dictionary_bytes, n
            ));
        }
        out.push_str(&format!(
            "{:<24} {:>9} {:>11} {:<22} {:>9}\n",
            "branch", "intensity", "use-case", "settings", "basket-kb"
        ));
        for p in &self.plans {
            let intensity = match p.intensity {
                Some(i) => format!("{i:.3}"),
                None => "-".to_string(),
            };
            out.push_str(&format!(
                "{:<24} {:>9} {:>11} {:<22} {:>9.1}\n",
                p.name,
                intensity,
                format!("{:?}", p.decision.use_case).to_lowercase(),
                p.decision.settings.label(),
                p.decision.basket_bytes as f64 / 1024.0
            ));
        }
        if !self.gaps.is_empty() {
            let dropped: u64 = self.gaps.iter().map(|g| g.n_entries).sum();
            out.push_str(&format!(
                "salvage: dropped {dropped} entries across {} gap(s):\n",
                self.gaps.len()
            ));
            for g in &self.gaps {
                out.push_str(&format!(
                    "  entries [{}, {}) lost to damage\n",
                    g.first_entry,
                    g.end_entry()
                ));
            }
        }
        out
    }
}

/// Resolve every branch's repack plan for a file: analyzer features ×
/// recorded profile → [`Planner::plan_repack`] per branch. Exposed
/// separately from [`repack_file`] so `inspect --replan` and the tests
/// can see the decision surface without rewriting anything.
pub fn plan_branches(src: &Path, opts: &RepackOptions) -> Result<Vec<BranchPlan>> {
    if let Some(fb) = &opts.profile {
        if fb.scans <= 0.0 {
            bail!("profile records no scans — nothing to weight the plan by");
        }
    }
    let workers = effective_workers(opts.workers);
    let profiles = analyze_tree(src, workers)?;
    let planner = Planner::new(opts.use_case, FeatureSource::Native);
    let mut plans = Vec::with_capacity(profiles.len());
    for p in &profiles {
        let intensity = opts
            .profile
            .as_ref()
            .map(|fb| fb.intensity(&p.name, p.logical_bytes));
        // The observed per-scan decoded window in logical bytes: the
        // profile's window-stride signal for re-chunk sizing.
        let window_bytes = opts
            .profile
            .as_ref()
            .and_then(|fb| fb.get(&p.name))
            .and_then(|b| (b.scans > 0.0).then(|| b.logical_bytes / b.scans));
        let decision =
            planner.plan_repack(p.features.as_ref(), intensity, window_bytes, opts.target_basket_bytes);
        // Small-basket branches (average basket below the smallest
        // analyzer bucket) feed the shared trained dictionary.
        let dict_sampled = opts.dict_budget > 0
            && p.baskets > 0
            && p.logical_bytes / p.baskets as u64 < BUCKETS[0] as u64;
        plans.push(BranchPlan {
            branch_id: p.branch_id,
            name: p.name.clone(),
            intensity,
            decision,
            dict_sampled,
        });
    }
    Ok(plans)
}

/// Rewrite `src` into `dst` under the plan [`plan_branches`] resolves:
/// per-branch codec/preconditioner/entropy settings, re-chunked basket
/// boundaries, and (when small-basket branches exist) one shared trained
/// dictionary record. Strict by default — any unreadable basket fails
/// the rewrite and removes the partial output; with
/// [`RepackOptions::salvage`] the intact rows are kept and dropped spans
/// are reported. See the module docs for the guarantees.
pub fn repack_file(src: &Path, dst: &Path, opts: &RepackOptions) -> Result<RepackReport> {
    let result = repack_file_inner(src, dst, opts);
    if result.is_err() {
        // Never leave a half-written output behind a failed repack.
        let _ = std::fs::remove_file(dst);
    }
    result
}

fn effective_workers(workers: usize) -> usize {
    if workers > 0 {
        workers
    } else {
        ReadAhead::default().workers
    }
}

fn repack_file_inner(src: &Path, dst: &Path, opts: &RepackOptions) -> Result<RepackReport> {
    let workers = effective_workers(opts.workers);
    let reader = ParallelTreeReader::open(src, ReadAhead::with_workers(workers))?;
    let meta = reader.meta.clone();
    let plans = plan_branches(src, opts)?;

    // Train the shared dictionary from the small-basket branches' logical
    // payloads before the writer spins up (workers seed their engines
    // with it at construction).
    let dictionary = train_dictionary(&reader, &plans, opts)?;

    let writer = RecordWriter::create(dst)
        .with_context(|| format!("creating repack output {}", dst.display()))?;
    let mut wcfg = PipelineConfig::default();
    if opts.workers > 0 {
        wcfg.workers = opts.workers;
        wcfg.queue_depth = 2 * opts.workers;
    }
    wcfg.dictionary = dictionary.clone();
    let mut sink = ParallelSink::new(writer, wcfg);

    let mut chunkers: Vec<Rechunker> = meta
        .branches
        .iter()
        .enumerate()
        .map(|(b, def)| Rechunker::new(b as u32, def.ty, &plans[b].decision))
        .collect();

    let mut gaps: Vec<GapSpan> = Vec::new();
    let mut damage: Vec<DamageRecord> = Vec::new();
    let n_entries_out;

    if !opts.salvage {
        // Strict streaming pass: the directory is sorted by
        // (branch_id, basket_index), so one scan over it delivers
        // branch-major in entry order and memory stays bounded by the
        // read-ahead window plus one accumulating basket per branch.
        let mut scan = reader.scan(meta.baskets.clone())?;
        while let Some(item) = scan.next_basket() {
            let (loc, content) = item?;
            let ch = chunkers
                .get_mut(loc.branch_id as usize)
                .with_context(|| format!("basket for unknown branch {}", loc.branch_id))?;
            if loc.first_entry != ch.source_entries() {
                bail!(
                    "branch {}: basket {} starts at entry {}, expected {} — source entry spans \
                     are not contiguous",
                    loc.branch_id,
                    loc.basket_index,
                    loc.first_entry,
                    ch.source_entries()
                );
            }
            ch.push_basket(&content, &mut sink)?;
            scan.recycle(content);
        }
        n_entries_out = meta.n_entries;
    } else {
        // Salvage pass: decode every column degraded, then drop each
        // damaged entry span from *every* branch so the output stays
        // rectangular, and report exactly what was lost.
        let n = meta.n_entries as usize;
        let mut keep = vec![true; n];
        let mut columns = Vec::with_capacity(meta.branches.len());
        for b in 0..meta.branches.len() as u32 {
            let col = reader.read_range_salvage(b, 0..meta.n_entries)?;
            for g in &col.gaps {
                for e in g.first_entry..g.end_entry() {
                    keep[e as usize] = false;
                }
            }
            damage.extend(col.damage.iter().cloned());
            columns.push(col);
        }
        let mut i = 0usize;
        while i < n {
            if keep[i] {
                i += 1;
                continue;
            }
            let start = i;
            while i < n && !keep[i] {
                i += 1;
            }
            push_gap(
                &mut gaps,
                GapSpan { first_entry: start as u64, n_entries: (i - start) as u64 },
            );
        }
        n_entries_out = keep.iter().filter(|&&k| k).count() as u64;
        let mut buf = Vec::new();
        for (b, col) in columns.iter().enumerate() {
            let ch = &mut chunkers[b];
            let mut values = col.values.iter();
            let mut gi = 0usize;
            for e in 0..n as u64 {
                while gi < col.gaps.len() && e >= col.gaps[gi].end_entry() {
                    gi += 1;
                }
                if gi < col.gaps.len() && e >= col.gaps[gi].first_entry {
                    continue; // lost in this branch: no value to consume
                }
                let v = values
                    .next()
                    .with_context(|| format!("branch {b}: salvage column ran dry at entry {e}"))?;
                if keep[e as usize] {
                    buf.clear();
                    v.serialize(&mut buf);
                    ch.push_entry(&buf, &mut sink)?;
                }
            }
            if values.next().is_some() {
                bail!("branch {b}: salvage column has surplus values");
            }
        }
    }

    for ch in &mut chunkers {
        ch.finish(&mut sink)?;
        if ch.written_entries() != n_entries_out {
            bail!(
                "branch {}: wrote {} entries, expected {}",
                ch.branch_id,
                ch.written_entries(),
                n_entries_out
            );
        }
    }

    let mut locs = sink.finish()?;
    locs.sort_by_key(|l| (l.branch_id, l.basket_index));
    let baskets_out = locs.len();
    let branches: Vec<BranchDef> = meta
        .branches
        .iter()
        .zip(&plans)
        .map(|(def, p)| {
            let mut d = def.clone();
            d.settings = Some(p.decision.settings);
            d
        })
        .collect();
    let mut out_meta = TreeMeta {
        name: meta.name.clone(),
        branches,
        default_settings: meta.default_settings,
        n_entries: n_entries_out,
        baskets: locs,
        dictionary_offset: None,
    };
    let mut writer = sink.take_writer().context("repack writer missing after finish")?;
    if !dictionary.is_empty() {
        let off = writer.append(RecordKind::Dictionary, &dictionary)?;
        out_meta.dictionary_offset = Some(off);
    }
    writer.close(&out_meta)?;

    let bytes_in = std::fs::metadata(src)?.len();
    let bytes_out = std::fs::metadata(dst)?.len();
    Ok(RepackReport {
        plans,
        n_entries_in: meta.n_entries,
        n_entries_out,
        baskets_in: meta.baskets.len(),
        baskets_out,
        bytes_in,
        bytes_out,
        dictionary_bytes: dictionary.len(),
        gaps,
        damage,
    })
}

/// Train the shared dictionary from up to [`DICT_SAMPLES_PER_BRANCH`]
/// leading baskets of every dictionary-eligible branch. Returns empty
/// when training is disabled or no branch qualifies.
fn train_dictionary(
    reader: &ParallelTreeReader,
    plans: &[BranchPlan],
    opts: &RepackOptions,
) -> Result<Vec<u8>> {
    if opts.dict_budget == 0 || !plans.iter().any(|p| p.dict_sampled) {
        return Ok(Vec::new());
    }
    let mut locs = Vec::new();
    for p in plans.iter().filter(|p| p.dict_sampled) {
        locs.extend(
            reader
                .baskets_for(p.branch_id)
                .into_iter()
                .take(DICT_SAMPLES_PER_BRANCH),
        );
    }
    // One monotonic sweep over the sample baskets.
    locs.sort_by_key(|l| l.file_offset);
    let mode = if opts.salvage { ScanMode::Salvage } else { ScanMode::Strict };
    let mut scan = reader.scan_with_mode(locs, mode)?;
    let mut samples: Vec<Vec<u8>> = Vec::new();
    while let Some(item) = scan.next_basket() {
        let (_, content) = item?;
        // The training sample is the basket's logical payload exactly as
        // the engine compresses it: element data, then the big-endian
        // end-of-entry offsets.
        let mut sample =
            Vec::with_capacity(content.data.len() + 4 * content.offsets.len());
        sample.extend_from_slice(&content.data);
        for off in content.offsets.iter() {
            sample.extend_from_slice(&off.to_be_bytes());
        }
        samples.push(sample);
        scan.recycle(content);
    }
    Ok(crate::zstd::dict::train_from_corpus(&samples, opts.dict_budget))
}

/// Per-branch re-chunking accumulator: entries stream in (from decoded
/// source baskets or salvage columns), baskets of the planned target size
/// stream out, with jagged end-of-entry offsets rebased to each new
/// basket's data and entry spans kept contiguous from 0.
struct Rechunker {
    branch_id: u32,
    jagged: bool,
    elem_size: usize,
    target: usize,
    settings: Settings,
    basket_index: u32,
    first_entry: u64,
    n_entries: u32,
    source_entries: u64,
    data: Vec<u8>,
    offsets: Vec<u32>,
}

impl Rechunker {
    fn new(branch_id: u32, ty: BranchType, decision: &RepackDecision) -> Self {
        Self {
            branch_id,
            jagged: ty.is_var(),
            elem_size: ty.elem_size(),
            target: decision.basket_bytes.max(1),
            settings: decision.settings,
            basket_index: 0,
            first_entry: 0,
            n_entries: 0,
            source_entries: 0,
            data: Vec::new(),
            offsets: Vec::new(),
        }
    }

    /// Source entries consumed so far (for span-continuity checks).
    fn source_entries(&self) -> u64 {
        self.source_entries
    }

    /// Entries flushed into output baskets (valid after [`finish`](Self::finish)).
    fn written_entries(&self) -> u64 {
        self.first_entry
    }

    /// Feed one decoded source basket through, re-splitting at the target.
    fn push_basket<S: BasketSink>(&mut self, content: &BasketContent, sink: &mut S) -> Result<()> {
        if self.jagged {
            let mut prev = 0usize;
            for off in content.offsets.iter() {
                let end = *off as usize;
                if end < prev || end > content.data.len() {
                    bail!("branch {}: corrupt offset array in decoded basket", self.branch_id);
                }
                self.push_entry(&content.data[prev..end], sink)?;
                prev = end;
            }
        } else {
            // Fixed-width fast path: bulk-copy as many whole entries as
            // fit before each flush instead of one memcpy per entry.
            let esz = self.elem_size;
            let total = content.n_entries as usize;
            let mut i = 0usize;
            while i < total {
                let room = self.target.saturating_sub(self.data.len());
                let fit = (room / esz).max(1).min(total - i);
                self.data.extend_from_slice(&content.data[i * esz..(i + fit) * esz]);
                self.n_entries += fit as u32;
                self.source_entries += fit as u64;
                i += fit;
                if self.data.len() >= self.target {
                    self.flush(sink)?;
                }
            }
        }
        Ok(())
    }

    /// Append one entry's element bytes; flush when the accumulated
    /// logical size (data + offset array) reaches the target — the same
    /// rule [`TreeWriter`](crate::rfile::TreeWriter) flushes under.
    fn push_entry<S: BasketSink>(&mut self, entry: &[u8], sink: &mut S) -> Result<()> {
        self.data.extend_from_slice(entry);
        if self.jagged {
            self.offsets.push(self.data.len() as u32);
        }
        self.n_entries += 1;
        self.source_entries += 1;
        if self.data.len() + 4 * self.offsets.len() >= self.target {
            self.flush(sink)?;
        }
        Ok(())
    }

    fn flush<S: BasketSink>(&mut self, sink: &mut S) -> Result<()> {
        if self.n_entries == 0 {
            return Ok(());
        }
        let basket = PendingBasket {
            branch_id: self.branch_id,
            basket_index: self.basket_index,
            first_entry: self.first_entry,
            n_entries: self.n_entries,
            data: std::mem::take(&mut self.data),
            offsets: std::mem::take(&mut self.offsets),
        };
        self.basket_index += 1;
        self.first_entry += self.n_entries as u64;
        self.n_entries = 0;
        sink.submit(basket, self.settings)
    }

    /// Flush the final partial basket.
    fn finish<S: BasketSink>(&mut self, sink: &mut S) -> Result<()> {
        self.flush(sink)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::Algorithm;
    use crate::rfile::meta::BasketLoc;

    /// A sink that keeps the submitted baskets for inspection.
    struct CollectSink(Vec<(PendingBasket, Settings)>);

    impl BasketSink for CollectSink {
        fn submit(&mut self, basket: PendingBasket, settings: Settings) -> Result<()> {
            self.0.push((basket, settings));
            Ok(())
        }
        fn finish(&mut self) -> Result<Vec<BasketLoc>> {
            Ok(Vec::new())
        }
    }

    fn decision(basket_bytes: usize) -> RepackDecision {
        RepackDecision {
            use_case: UseCase::Balanced,
            settings: Settings::new(Algorithm::Lz4, 1),
            basket_bytes,
        }
    }

    #[test]
    fn rechunker_preserves_fixed_entries_and_spans() {
        let mut sink = CollectSink(Vec::new());
        let mut ch = Rechunker::new(0, BranchType::F32, &decision(64));
        // 3 source baskets of 10/7/13 entries → 30 entries of 4 bytes.
        let mut next = 0u32;
        for n in [10u32, 7, 13] {
            let mut data = Vec::new();
            for _ in 0..n {
                data.extend_from_slice(&next.to_be_bytes());
                next += 1;
            }
            let content = BasketContent { n_entries: n, data, offsets: Vec::new() };
            ch.push_basket(&content, &mut sink).unwrap();
        }
        ch.finish(&mut sink).unwrap();
        assert_eq!(ch.source_entries(), 30);
        assert_eq!(ch.written_entries(), 30);
        // Spans contiguous from 0, indexes consecutive, data concatenates
        // back to the source byte stream, every basket hits the target
        // except possibly the last.
        let mut expect_first = 0u64;
        let mut all = Vec::new();
        for (i, (b, s)) in sink.0.iter().enumerate() {
            assert_eq!(b.basket_index, i as u32);
            assert_eq!(b.first_entry, expect_first);
            assert!(b.offsets.is_empty());
            assert_eq!(s.algorithm, Algorithm::Lz4);
            if i + 1 < sink.0.len() {
                assert!(b.data.len() >= 64, "basket {i} under target");
            }
            expect_first += b.n_entries as u64;
            all.extend_from_slice(&b.data);
        }
        assert_eq!(expect_first, 30);
        let expected: Vec<u8> = (0u32..30).flat_map(|v| v.to_be_bytes()).collect();
        assert_eq!(all, expected);
    }

    #[test]
    fn rechunker_rebases_jagged_offsets() {
        let mut sink = CollectSink(Vec::new());
        let mut ch = Rechunker::new(2, BranchType::VarU8, &decision(24));
        // Two source baskets of jagged entries with varying lengths
        // (including empty entries).
        let entries: Vec<Vec<u8>> = vec![
            vec![1, 2, 3],
            vec![],
            vec![4; 10],
            vec![5],
            vec![6, 7],
            vec![],
            vec![8; 30], // bigger than the whole target on its own
            vec![9, 10],
        ];
        for half in entries.chunks(4) {
            let mut data = Vec::new();
            let mut offsets = Vec::new();
            for e in half {
                data.extend_from_slice(e);
                offsets.push(data.len() as u32);
            }
            let content =
                BasketContent { n_entries: half.len() as u32, data, offsets };
            ch.push_basket(&content, &mut sink).unwrap();
        }
        ch.finish(&mut sink).unwrap();
        assert_eq!(ch.written_entries(), entries.len() as u64);
        // Reassemble the entries from the rewritten baskets: offsets must
        // be basket-relative ends in order, and the entry bytes identical.
        let mut got: Vec<Vec<u8>> = Vec::new();
        let mut expect_first = 0u64;
        for (i, (b, _)) in sink.0.iter().enumerate() {
            assert_eq!(b.basket_index, i as u32);
            assert_eq!(b.first_entry, expect_first);
            assert_eq!(b.offsets.len(), b.n_entries as usize);
            let mut prev = 0usize;
            for &end in &b.offsets {
                let end = end as usize;
                assert!(end >= prev && end <= b.data.len());
                got.push(b.data[prev..end].to_vec());
                prev = end;
            }
            assert_eq!(prev, b.data.len(), "basket {i} has trailing bytes");
            expect_first += b.n_entries as u64;
        }
        assert_eq!(got, entries);
    }

    #[test]
    fn rechunker_flush_rule_counts_offset_array() {
        // 8 one-byte jagged entries with a 16-byte target: the offset
        // array (4 bytes/entry) must count toward the flush rule, so
        // baskets split well before 16 data bytes accumulate.
        let mut sink = CollectSink(Vec::new());
        let mut ch = Rechunker::new(0, BranchType::VarU8, &decision(16));
        for i in 0u8..8 {
            ch.push_entry(&[i], &mut sink).unwrap();
        }
        ch.finish(&mut sink).unwrap();
        assert!(sink.0.len() >= 2, "offset array ignored by flush rule");
        for (b, _) in &sink.0 {
            assert!(b.data.len() + 4 * b.offsets.len() <= 16 + 5);
        }
    }
}
