//! Concurrent multi-scan scheduler: the serving layer.
//!
//! A [`ScanServer`] owns a **corpus** of RFIL files, ONE shared pool of
//! decode workers, and a sharded decoded-basket cache
//! ([`super::cache::BasketCache`]). Many projection / entry-range queries
//! run concurrently; each gets a per-query [`ServeStream`] that plugs into
//! the same reorder/latch machinery single-reader scans use
//! ([`ProjectionScan`]/[`ProjectionReader`] are generic over
//! [`BasketStream`]).
//!
//! ```text
//!   query()──▶ admission (≤ max_scans active, FIFO)──▶ per-scan window
//!                                                      (≤ queue_depth
//!                                                       outstanding locs)
//!        issue: cache hit ──────────────▶ deliver Arc payload directly
//!               miss, decode in flight ─▶ coalesce (join the waiter list)
//!               miss, fresh ───────────▶ shared job queue ─▶ N workers
//!                                                             │ decode,
//!                                            cache.insert ◀───┘ then fan
//!                                            out to every waiting scan
//! ```
//!
//! Scheduling properties:
//!
//! * **Single-flight decode** — a `pending` registry keyed on
//!   [`CacheKey`] guarantees each basket is decoded at most once no
//!   matter how many scans want it concurrently; late arrivals join the
//!   waiter list instead of enqueueing a duplicate job. Together with the
//!   cache this gives the warm-cache invariant the integration suite
//!   asserts: N identical concurrent scans decode each basket exactly
//!   once.
//! * **Admission control** — at most `max_scans` scans are *active*
//!   (issuing work); later queries queue FIFO and start the moment a slot
//!   frees. Each active scan keeps at most `queue_depth` baskets
//!   outstanding, so one huge cold scan cannot monopolize the worker pool
//!   against small hot ones.
//! * **Damage isolation** — a basket that fails to read/decode is
//!   reported to every waiting scan (strict scans error, salvage scans
//!   record a gap) and is **never cached**.
//! * **Per-query metrics** — [`QueryStats`]: admission queue wait, decode
//!   CPU time, baskets/bytes served from cache vs disk, coalesced joins.
//!
//! Lock order: the scheduler takes `state` then (inside `issue`) a cache
//! shard lock; workers take a shard lock and *then* `state`, never
//! nested. Delivery channels are unbounded but effectively bounded by the
//! per-scan window (`queue_depth`), so sends never block while a lock is
//! held.

use crate::compression::Engine;
use crate::coordinator::cache::{BasketCache, CacheKey, CacheStats};
use crate::coordinator::metrics::{Metrics, Snapshot};
use crate::coordinator::projection::{
    ProjectionPlan, ProjectionReader, ProjectionScan, RowBatch,
};
use crate::coordinator::read_pipeline::{
    decode_raw_basket, BasketStream, DamageRecord, DecodedBasket, Delivery, ScanMode,
};
use crate::coordinator::PrefetchOrder;
use crate::rfile::basket::BasketContent;
use crate::rfile::branch::Value;
use crate::rfile::format::RecordKind;
use crate::rfile::meta::{BasketLoc, GapSpan, TreeMeta};
use crate::rfile::reader::TreeReader;
use crate::rfile::source::{
    compose_chain, read_record_from, FaultStats, FileId, IoConfig, IoStats, RemotePacing,
    SourceChain,
};
use crate::runtime::ReadFeedback;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Shared decode worker threads.
    pub workers: usize,
    /// Scans allowed to issue work concurrently; later queries wait FIFO.
    pub max_scans: usize,
    /// Max outstanding (issued, unconsumed) baskets per scan — the
    /// fairness/memory window.
    pub queue_depth: usize,
    /// Decoded-basket cache budget in bytes (0 disables caching).
    pub cache_bytes: u64,
    /// Cache shard count (rounded up to a power of two).
    pub cache_shards: usize,
    /// Default I/O backend configuration for every corpus file
    /// (overridable per file via [`ScanServer::from_paths_with_io`]).
    /// Remote-simulation latency is paced with [`RemotePacing::Deferred`]
    /// here: workers never sleep — the wait is charged to the requesting
    /// query's delivery instead, so a slow file cannot stall the shared
    /// pool.
    pub io: IoConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .saturating_sub(1)
            .max(1);
        Self {
            workers,
            max_scans: 8,
            queue_depth: 2 * workers,
            cache_bytes: 256 << 20,
            cache_shards: 16,
            io: IoConfig::default(),
        }
    }
}

/// One file of the server's corpus: identity, parsed metadata, dictionary.
pub struct CorpusFile {
    /// Lookup name (the file stem for [`ScanServer::open_corpus`]).
    pub name: String,
    pub path: PathBuf,
    /// Content identity used in cache keys.
    pub file_id: FileId,
    pub meta: TreeMeta,
    /// How workers read this file's bytes (defaults to
    /// [`ServeConfig::io`]; per-file overrides model mixed corpora, e.g.
    /// one file on local disk next to one behind a simulated remote).
    pub io: IoConfig,
    dictionary: Arc<Vec<u8>>,
}

/// A query against the corpus.
#[derive(Debug, Clone)]
pub struct Query {
    /// Corpus file name (see [`CorpusFile::name`]).
    pub file: String,
    /// Branch names to project; empty means **all** branches in schema
    /// order (the all-branch row surface, no name round-trip).
    pub branches: Vec<String>,
    /// Optional `[first, last)` entry window.
    pub entries: Option<(u64, u64)>,
    /// Damage handling ([`ScanMode::Salvage`] reads around casualties).
    pub mode: ScanMode,
}

impl Query {
    /// Whole-file, all-branch strict query.
    pub fn all(file: &str) -> Self {
        Query { file: file.to_string(), branches: Vec::new(), entries: None, mode: ScanMode::Strict }
    }

    /// Strict projection of `branches`.
    pub fn project(file: &str, branches: &[&str]) -> Self {
        Query {
            file: file.to_string(),
            branches: branches.iter().map(|s| s.to_string()).collect(),
            entries: None,
            mode: ScanMode::Strict,
        }
    }

    /// Narrow to the entry window `[first, last)` (builder style).
    pub fn entries(mut self, first: u64, last: u64) -> Self {
        self.entries = Some((first, last));
        self
    }

    /// Set the damage-handling mode (builder style).
    pub fn mode(mut self, mode: ScanMode) -> Self {
        self.mode = mode;
        self
    }
}

/// Per-query counters, updated live while the scan runs.
#[derive(Debug, Default)]
struct QueryMetrics {
    queue_wait_nanos: AtomicU64,
    decode_nanos: AtomicU64,
    baskets_decoded: AtomicU64,
    baskets_from_cache: AtomicU64,
    baskets_coalesced: AtomicU64,
    bytes_from_cache: AtomicU64,
    bytes_from_disk: AtomicU64,
    read_retries: AtomicU64,
}

/// Snapshot of one query's scheduling/decode accounting
/// ([`ServeQuery::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueryStats {
    /// Time between submission and admission (zero when admitted at once).
    pub queue_wait: Duration,
    /// Worker CPU time spent decoding baskets this query requested first.
    pub decode_nanos: u64,
    /// Baskets this query caused to be decoded from disk.
    pub baskets_decoded: u64,
    /// Baskets served straight from the decoded-basket cache.
    pub baskets_from_cache: u64,
    /// Baskets joined onto another scan's in-flight decode.
    pub baskets_coalesced: u64,
    /// Logical bytes served from the cache (incl. coalesced joins).
    pub bytes_from_cache: u64,
    /// Compressed bytes read from disk for this query's decodes.
    pub bytes_from_disk: u64,
    /// Transient read failures retried while serving *this query's*
    /// decode jobs. Charged per job from the per-chain counter deltas, so
    /// concurrent queries against the same file never double-count.
    pub read_retries: u64,
}

impl QueryMetrics {
    fn stats(&self) -> QueryStats {
        QueryStats {
            queue_wait: Duration::from_nanos(self.queue_wait_nanos.load(Ordering::Relaxed)),
            decode_nanos: self.decode_nanos.load(Ordering::Relaxed),
            baskets_decoded: self.baskets_decoded.load(Ordering::Relaxed),
            baskets_from_cache: self.baskets_from_cache.load(Ordering::Relaxed),
            baskets_coalesced: self.baskets_coalesced.load(Ordering::Relaxed),
            bytes_from_cache: self.bytes_from_cache.load(Ordering::Relaxed),
            bytes_from_disk: self.bytes_from_disk.load(Ordering::Relaxed),
            read_retries: self.read_retries.load(Ordering::Relaxed),
        }
    }
}

/// One decoded (or failed) basket travelling scheduler/worker → scan.
struct ScanDone {
    loc: BasketLoc,
    result: Result<Arc<BasketContent>, String>,
    /// Simulated-remote availability deadline: the banked
    /// ([`RemotePacing::Deferred`]) latency this job incurred, converted
    /// to an absolute instant. The consuming stream sleeps until it on
    /// its *own* thread — workers and unrelated scans never pay it.
    ready_at: Option<Instant>,
}

/// A basket decode the shared workers must perform. `origin` is the
/// query whose request created the job (charged for the decode).
struct DecodeJob {
    key: CacheKey,
    loc: BasketLoc,
    file: usize,
    origin: Arc<QueryMetrics>,
}

/// Scheduler-side state of one live scan.
struct ScanState {
    file: usize,
    /// Plan locs in submission order.
    locs: Vec<BasketLoc>,
    /// Next loc index to issue.
    next: usize,
    /// Issued but not yet consumed by the scan's stream.
    inflight: usize,
    done_tx: Sender<ScanDone>,
    submitted: Instant,
    admitted: bool,
    query: Arc<QueryMetrics>,
}

/// Mutable scheduler state, one mutex for all of it (the hot per-basket
/// work — I/O and decode — happens outside this lock).
struct SchedState {
    queue: VecDeque<DecodeJob>,
    scans: HashMap<u64, ScanState>,
    /// Scans submitted but not yet admitted, FIFO.
    waiting: VecDeque<u64>,
    active: usize,
    peak_active: usize,
    next_scan_id: u64,
    /// Keys with a decode in flight → scan ids waiting for it (origin
    /// first). The single-flight registry.
    pending: HashMap<CacheKey, Vec<u64>>,
    shutdown: bool,
}

/// Everything the worker threads and streams share.
struct ServerCore {
    files: Vec<CorpusFile>,
    by_name: HashMap<String, usize>,
    cache: BasketCache,
    metrics: Arc<Metrics>,
    cfg: ServeConfig,
    state: Mutex<SchedState>,
    work_ready: Condvar,
    /// Physical-read counters aggregated across every worker chain.
    io_stats: Arc<IoStats>,
    /// Injected-fault counters (all zero unless a file's [`IoConfig`]
    /// carries a fault spec — the integration tests' substrate).
    fault_stats: Arc<FaultStats>,
    /// Server-lifetime retry total (per-query attribution happens via
    /// per-chain deltas in `decode_job`; this is the metrics-snapshot
    /// cumulative).
    retry_total: Arc<AtomicU64>,
}

impl ServerCore {
    /// Issue more work for `scan_id` up to its window. Cache hits deliver
    /// immediately; misses either coalesce onto an in-flight decode or
    /// enqueue a fresh job. Caller holds the state lock.
    fn issue(&self, st: &mut SchedState, scan_id: u64) {
        let mut notify = false;
        loop {
            let Some(scan) = st.scans.get_mut(&scan_id) else { break };
            if !scan.admitted || scan.inflight >= self.cfg.queue_depth || scan.next >= scan.locs.len()
            {
                break;
            }
            let loc = scan.locs[scan.next];
            scan.next += 1;
            scan.inflight += 1;
            let key = CacheKey {
                file: self.files[scan.file].file_id,
                branch_id: loc.branch_id,
                basket_index: loc.basket_index,
            };
            let query = Arc::clone(&scan.query);
            let done_tx = scan.done_tx.clone();
            let file = scan.file;
            if let Some(content) = self.cache.get(&key) {
                query.baskets_from_cache.fetch_add(1, Ordering::Relaxed);
                query
                    .bytes_from_cache
                    .fetch_add(BasketCache::payload_bytes(&content), Ordering::Relaxed);
                let _ = done_tx.send(ScanDone { loc, result: Ok(content), ready_at: None });
                continue;
            }
            if let Some(waiters) = st.pending.get_mut(&key) {
                waiters.push(scan_id);
                query.baskets_coalesced.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            st.pending.insert(key, vec![scan_id]);
            st.queue.push_back(DecodeJob { key, loc, file, origin: query });
            notify = true;
        }
        if notify {
            self.work_ready.notify_all();
        }
    }

    /// Admit waiting scans while slots are free. Caller holds the lock.
    fn admit(&self, st: &mut SchedState) {
        while st.active < self.cfg.max_scans {
            let Some(id) = st.waiting.pop_front() else { break };
            let Some(scan) = st.scans.get_mut(&id) else { continue };
            scan.admitted = true;
            scan.query
                .queue_wait_nanos
                .store(scan.submitted.elapsed().as_nanos() as u64, Ordering::Relaxed);
            st.active += 1;
            st.peak_active = st.peak_active.max(st.active);
            self.issue(st, id);
        }
    }

    /// A stream consumed one delivery: shrink its window, top it back up.
    fn consumed(&self, scan_id: u64) {
        let mut st = self.state.lock().unwrap();
        if let Some(scan) = st.scans.get_mut(&scan_id) {
            scan.inflight = scan.inflight.saturating_sub(1);
        }
        self.issue(&mut st, scan_id);
    }

    /// A scan finished (drained, failed, or dropped): release its
    /// admission slot and admit the next waiter. Idempotent.
    fn finish_scan(&self, scan_id: u64) {
        let mut st = self.state.lock().unwrap();
        let Some(scan) = st.scans.remove(&scan_id) else { return };
        if scan.admitted {
            st.active -= 1;
        } else {
            st.waiting.retain(|&id| id != scan_id);
        }
        self.admit(&mut st);
    }

    /// Worker thread body: pop jobs, read + decode outside the lock,
    /// publish to the cache, fan the result out to every waiting scan.
    fn worker_loop(self: &Arc<Self>) {
        let mut engine = Engine::new();
        // Which file's dictionary the engine currently holds. Corpus files
        // differ, so the engine re-arms on every file switch (an empty
        // dictionary behaves exactly like no dictionary).
        let mut dict_for: Option<usize> = None;
        let mut chains: HashMap<usize, SourceChain> = HashMap::new();
        let mut raw = Vec::new();
        let mut logical_scratch = Vec::new();
        loop {
            let job = {
                let mut st = self.state.lock().unwrap();
                loop {
                    if st.shutdown {
                        return;
                    }
                    if let Some(j) = st.queue.pop_front() {
                        break j;
                    }
                    st = self.work_ready.wait(st).unwrap();
                }
            };
            let (result, ready_at) = self.decode_job(
                &job,
                &mut engine,
                &mut dict_for,
                &mut chains,
                &mut raw,
                &mut logical_scratch,
            );
            if let Ok(content) = &result {
                // Publish before fan-out so a scan that misses the pending
                // registry a microsecond later hits the cache instead.
                // Damaged baskets never reach this insert.
                self.cache.insert(job.key, Arc::clone(content));
            }
            let mut st = self.state.lock().unwrap();
            let waiters = st.pending.remove(&job.key).unwrap_or_default();
            for (i, w) in waiters.iter().enumerate() {
                let Some(scan) = st.scans.get(w) else { continue };
                if i > 0 {
                    // Coalesced joins are served by the shared decode: count
                    // their bytes as cache-served, same as a plain hit.
                    if let Ok(content) = &result {
                        scan.query
                            .bytes_from_cache
                            .fetch_add(BasketCache::payload_bytes(content), Ordering::Relaxed);
                    }
                }
                let _ =
                    scan.done_tx.send(ScanDone { loc: job.loc, result: result.clone(), ready_at });
            }
        }
    }

    /// Read and decode one basket (no scheduler locks held). Returns the
    /// result plus the delivery deadline the simulated remote banked for
    /// this job (`None` on local backends). Retries observed by this
    /// job's chain are charged to the *originating* query only.
    fn decode_job(
        &self,
        job: &DecodeJob,
        engine: &mut Engine,
        dict_for: &mut Option<usize>,
        chains: &mut HashMap<usize, SourceChain>,
        raw: &mut Vec<u8>,
        logical_scratch: &mut Vec<u8>,
    ) -> (Result<Arc<BasketContent>, String>, Option<Instant>) {
        let file = &self.files[job.file];
        if *dict_for != Some(job.file) {
            engine.set_dictionary(file.dictionary.as_ref().clone());
            *dict_for = Some(job.file);
        }
        // Worker-local source chain per file: the backend layers are
        // stateful (merge buffers, pacing windows), so they are never
        // shared across threads. The coalescing plan is the file's whole
        // basket directory; the remote pipeline window is the per-scan
        // queue depth (what a scan can keep outstanding).
        let chain = match chains.entry(job.file) {
            std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
            std::collections::hash_map::Entry::Vacant(v) => {
                let plan: Vec<(u64, u64)> =
                    file.meta.baskets.iter().map(|l| l.record_span()).collect();
                let chain = match compose_chain(
                    &file.path,
                    &file.io,
                    &plan,
                    self.cfg.queue_depth.max(1),
                    RemotePacing::Deferred,
                    Arc::clone(&self.io_stats),
                    Arc::clone(&self.fault_stats),
                    &[Arc::clone(&self.retry_total)],
                ) {
                    Ok(c) => c,
                    Err(e) => return (Err(format!("{e:#}")), None),
                };
                v.insert(chain)
            }
        };
        let retries_before = chain.retries.load(Ordering::Relaxed);
        let owed_before = chain.owed.load(Ordering::Relaxed);
        let result = (|| {
            let t0 = Instant::now();
            match read_record_from(&mut chain.source, job.loc.file_offset, raw) {
                Ok(RecordKind::Basket) => {}
                Ok(kind) => {
                    return Err(format!(
                        "expected basket record at {}, found {kind:?}",
                        job.loc.file_offset
                    ))
                }
                Err(e) => return Err(e.to_string()),
            }
            let mut content =
                BasketContent { n_entries: 0, data: Vec::new(), offsets: Vec::new() };
            decode_raw_basket(raw, &job.loc, engine, logical_scratch, &mut content)?;
            let elapsed = t0.elapsed();
            let logical = content.data.len() + 4 * content.offsets.len();
            self.metrics.record_basket(logical, raw.len(), elapsed);
            job.origin.decode_nanos.fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
            job.origin.baskets_decoded.fetch_add(1, Ordering::Relaxed);
            job.origin.bytes_from_disk.fetch_add(raw.len() as u64, Ordering::Relaxed);
            Ok(Arc::new(content))
        })();
        // Charge this job's chain-counter deltas (retries, banked remote
        // latency) to the query that requested it — including on failure,
        // where the retry layer may have burned all its attempts.
        let retries = chain.retries.load(Ordering::Relaxed).saturating_sub(retries_before);
        if retries > 0 {
            job.origin.read_retries.fetch_add(retries, Ordering::Relaxed);
        }
        let owed = chain.owed.load(Ordering::Relaxed).saturating_sub(owed_before);
        let ready_at = (owed > 0).then(|| Instant::now() + Duration::from_nanos(owed));
        (result, ready_at)
    }
}

/// Per-query delivery stream: the serving layer's [`BasketStream`].
/// Deliveries arrive in whatever order cache hits and worker skew produce;
/// the projection layer's per-slot parking restores per-branch order.
pub struct ServeStream {
    core: Arc<ServerCore>,
    scan_id: u64,
    done_rx: Receiver<ScanDone>,
    mode: ScanMode,
    branch_names: Arc<Vec<String>>,
    damage: Vec<DamageRecord>,
    delivered: u64,
    total: u64,
    /// Terminal (server shut down mid-scan): the stream ends.
    broken: bool,
    /// Admission slot released (idempotent guard).
    released: bool,
}

impl ServeStream {
    fn release(&mut self) {
        if !self.released {
            self.released = true;
            self.core.finish_scan(self.scan_id);
        }
    }
}

impl BasketStream for ServeStream {
    fn next_delivery(&mut self) -> Option<Result<Delivery>> {
        if self.broken || self.delivered >= self.total {
            self.release();
            return None;
        }
        match self.done_rx.recv() {
            Ok(d) => {
                // Deferred remote pacing: the payload "arrives" at its
                // banked deadline. Sleeping here — on this query's own
                // consumer thread — is the whole point of the deferral:
                // the worker that produced it moved on long ago, and
                // concurrent queries against fast files never wait.
                if let Some(t) = d.ready_at {
                    let now = Instant::now();
                    if t > now {
                        std::thread::sleep(t - now);
                    }
                }
                self.delivered += 1;
                self.core.consumed(self.scan_id);
                if self.delivered >= self.total {
                    // Fully delivered: free the admission slot now rather
                    // than waiting for the consumer to drop the reader.
                    self.release();
                }
                Some(match d.result {
                    Ok(content) => {
                        Ok(Delivery::Basket(d.loc, DecodedBasket::Shared(content)))
                    }
                    Err(e) => {
                        let branch = self
                            .branch_names
                            .get(d.loc.branch_id as usize)
                            .cloned()
                            .unwrap_or_else(|| format!("#{}", d.loc.branch_id));
                        let rec = DamageRecord { loc: d.loc, branch, error: e };
                        match self.mode {
                            ScanMode::Strict => Err(anyhow!("{rec}")),
                            ScanMode::Salvage => {
                                self.damage.push(rec.clone());
                                Ok(Delivery::Damaged(rec))
                            }
                        }
                    }
                })
            }
            Err(_) => {
                self.broken = true;
                self.release();
                Some(Err(anyhow!(
                    "scan server shut down ({} of {} baskets delivered)",
                    self.delivered,
                    self.total
                )))
            }
        }
    }

    fn recycle(&self, _content: DecodedBasket) {
        // Shared payloads belong to the cache; dropping the Arc is the
        // whole return protocol.
    }

    fn mode(&self) -> ScanMode {
        self.mode
    }

    fn damage(&self) -> &[DamageRecord] {
        &self.damage
    }
}

impl Drop for ServeStream {
    fn drop(&mut self) {
        self.release();
    }
}

/// A live query: a [`ProjectionReader`] over a [`ServeStream`], plus the
/// plan and per-query stats.
pub struct ServeQuery {
    reader: ProjectionReader<ServeStream>,
    plan: ProjectionPlan,
    metrics: Arc<QueryMetrics>,
}

impl ServeQuery {
    /// The executed prefetch plan (offset-sorted; inspect
    /// [`ProjectionPlan::is_monotonic_sweep`] etc.).
    pub fn plan(&self) -> &ProjectionPlan {
        &self.plan
    }

    /// The underlying projection reader (row batches, salvage gaps,
    /// branch stats — everything a single-reader projection offers).
    pub fn reader(&mut self) -> &mut ProjectionReader<ServeStream> {
        &mut self.reader
    }

    /// Drain into per-branch event-order columns
    /// (see [`ProjectionReader::read_columns`]).
    pub fn read_columns(&mut self) -> Result<Vec<Vec<Value>>> {
        self.reader.read_columns()
    }

    /// Next aligned row batch (see [`ProjectionReader::next_batch`]).
    pub fn next_batch(&mut self) -> Option<Result<RowBatch>> {
        self.reader.next_batch()
    }

    /// Per-branch read statistics accumulated so far.
    pub fn branch_stats(&self) -> &[crate::coordinator::BranchReadStats] {
        self.reader.branch_stats()
    }

    /// Row-level damage gaps (salvage mode).
    pub fn gaps(&self) -> &[GapSpan] {
        self.reader.gaps()
    }

    /// All damage observed (salvage mode).
    pub fn damage(&self) -> Vec<DamageRecord> {
        self.reader.damage()
    }

    /// This query's scheduling/decode accounting.
    pub fn stats(&self) -> QueryStats {
        self.metrics.stats()
    }

    /// Fold this query's per-branch reads into an access profile — the
    /// per-query observe hook for the adaptive replanner. Call after
    /// draining the query so the stats are complete.
    pub fn record_feedback(&self, fb: &mut ReadFeedback) {
        fb.record_scan(self.reader.branch_stats());
    }
}

/// The long-running scan server: corpus + worker pool + cache + scheduler.
///
/// ```no_run
/// use rootio::coordinator::{Query, ScanServer, ServeConfig};
///
/// let server = ScanServer::open_corpus("corpus/".as_ref(), ServeConfig::default()).unwrap();
/// let mut q = server.query(&Query::project("events", &["Muon_pt", "nMuon"])).unwrap();
/// let columns = q.read_columns().unwrap();
/// assert_eq!(columns.len(), 2);
/// println!("cache: {:?}", server.cache_stats());
/// ```
pub struct ScanServer {
    core: Arc<ServerCore>,
    workers: Vec<JoinHandle<()>>,
}

impl ScanServer {
    /// Serve every `*.rfil` file under `dir` (sorted by name; the corpus
    /// name of each file is its stem).
    pub fn open_corpus(dir: &Path, cfg: ServeConfig) -> Result<Self> {
        let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
            .with_context(|| format!("reading corpus dir {}", dir.display()))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("rfil"))
            .collect();
        paths.sort();
        if paths.is_empty() {
            bail!("no .rfil files in corpus dir {}", dir.display());
        }
        Self::from_paths(&paths, cfg)
    }

    /// Serve an explicit list of RFIL files (corpus names are file stems).
    /// Every file uses [`ServeConfig::io`].
    pub fn from_paths(paths: &[PathBuf], cfg: ServeConfig) -> Result<Self> {
        let specs: Vec<(PathBuf, IoConfig)> = paths.iter().map(|p| (p.clone(), cfg.io)).collect();
        Self::from_paths_with_io(&specs, cfg)
    }

    /// [`from_paths`](Self::from_paths) with a per-file [`IoConfig`] —
    /// the mixed-corpus entry point (e.g. one local pread file served
    /// next to one behind a 10 ms simulated remote).
    pub fn from_paths_with_io(specs: &[(PathBuf, IoConfig)], cfg: ServeConfig) -> Result<Self> {
        let mut files = Vec::with_capacity(specs.len());
        let mut by_name = HashMap::new();
        for (path, io) in specs {
            let serial = TreeReader::open(path)
                .with_context(|| format!("opening corpus file {}", path.display()))?;
            let name = path
                .file_stem()
                .and_then(|s| s.to_str())
                .map(|s| s.to_string())
                .unwrap_or_else(|| path.display().to_string());
            if by_name.insert(name.clone(), files.len()).is_some() {
                bail!("duplicate corpus file name '{name}'");
            }
            files.push(CorpusFile {
                name,
                path: path.clone(),
                file_id: FileId::of_path(path)?,
                meta: serial.meta.clone(),
                io: *io,
                dictionary: Arc::new(serial.dictionary().to_vec()),
            });
        }
        let core = Arc::new(ServerCore {
            files,
            by_name,
            cache: BasketCache::new(cfg.cache_bytes, cfg.cache_shards),
            metrics: Arc::new(Metrics::new()),
            cfg,
            state: Mutex::new(SchedState {
                queue: VecDeque::new(),
                scans: HashMap::new(),
                waiting: VecDeque::new(),
                active: 0,
                peak_active: 0,
                next_scan_id: 0,
                pending: HashMap::new(),
                shutdown: false,
            }),
            work_ready: Condvar::new(),
            io_stats: Arc::new(IoStats::default()),
            fault_stats: Arc::new(FaultStats::default()),
            retry_total: Arc::new(AtomicU64::new(0)),
        });
        let workers = (0..cfg.workers.max(1))
            .map(|_| {
                let core = Arc::clone(&core);
                std::thread::spawn(move || core.worker_loop())
            })
            .collect();
        Ok(ScanServer { core, workers })
    }

    /// The corpus being served.
    pub fn files(&self) -> &[CorpusFile] {
        &self.core.files
    }

    /// Submit a query. Returns immediately — admission control may delay
    /// *execution* (FIFO), but never the submission; the returned reader
    /// blocks on its first delivery until the scan is admitted.
    pub fn query(&self, q: &Query) -> Result<ServeQuery> {
        let &file_idx = self
            .core
            .by_name
            .get(&q.file)
            .ok_or_else(|| anyhow!("no corpus file '{}'", q.file))?;
        let meta = &self.core.files[file_idx].meta;
        let ids: Vec<u32> = if q.branches.is_empty() {
            (0..meta.branches.len() as u32).collect()
        } else {
            let names: Vec<&str> = q.branches.iter().map(|s| s.as_str()).collect();
            ProjectionPlan::resolve_names(meta, &names)?
        };
        let mut plan = ProjectionPlan::new(meta, &ids, PrefetchOrder::FileOffset)?;
        if let Some((a, b)) = q.entries {
            plan = plan.slice(a, b);
        }
        let branch_names: Arc<Vec<String>> =
            Arc::new(meta.branches.iter().map(|b| b.name.clone()).collect());
        let metrics = Arc::new(QueryMetrics::default());
        let (done_tx, done_rx) = std::sync::mpsc::channel::<ScanDone>();

        let scan_id = {
            let mut st = self.core.state.lock().unwrap();
            if st.shutdown {
                bail!("scan server is shutting down");
            }
            let scan_id = st.next_scan_id;
            st.next_scan_id += 1;
            st.scans.insert(
                scan_id,
                ScanState {
                    file: file_idx,
                    locs: plan.locs().to_vec(),
                    next: 0,
                    inflight: 0,
                    done_tx,
                    submitted: Instant::now(),
                    admitted: false,
                    query: Arc::clone(&metrics),
                },
            );
            st.waiting.push_back(scan_id);
            self.core.admit(&mut st);
            scan_id
        };

        let stream = ServeStream {
            core: Arc::clone(&self.core),
            scan_id,
            done_rx,
            mode: q.mode,
            branch_names,
            damage: Vec::new(),
            delivered: 0,
            total: plan.locs().len() as u64,
            broken: false,
            released: false,
        };
        let reader = ProjectionReader::new(ProjectionScan::new(stream, &plan), meta, &plan);
        Ok(ServeQuery { reader, plan, metrics })
    }

    /// Cache behaviour counters (hits/misses/evictions/residency).
    pub fn cache_stats(&self) -> CacheStats {
        self.core.cache.stats()
    }

    /// Aggregate decode metrics across every query served, with the cache
    /// hit/miss counters folded in. `Snapshot::baskets` counts **actual
    /// decodes** — the warm-cache invariant's witness.
    pub fn metrics_snapshot(&self) -> Snapshot {
        let cs = self.core.cache.stats();
        self.core.metrics.set_cache_counters(cs.hits, cs.misses);
        self.core.metrics.set_read_retries(self.core.retry_total.load(Ordering::Relaxed));
        self.core.metrics.set_io_counters(
            self.core.io_stats.syscalls(),
            self.core.io_stats.bytes_merged(),
            self.core.io_stats.requests_coalesced(),
        );
        self.core.metrics.snapshot()
    }

    /// Physical-read counters aggregated across every worker's source
    /// chain (also folded into [`metrics_snapshot`](Self::metrics_snapshot)).
    pub fn io_stats(&self) -> Arc<IoStats> {
        Arc::clone(&self.core.io_stats)
    }

    /// Injected-fault counters (zero unless some file's [`IoConfig`]
    /// carries a fault spec).
    pub fn fault_stats(&self) -> Arc<FaultStats> {
        Arc::clone(&self.core.fault_stats)
    }

    /// Highest number of concurrently-active (admitted) scans so far —
    /// the admission-control witness (`≤ max_scans` always).
    pub fn peak_active(&self) -> usize {
        self.core.state.lock().unwrap().peak_active
    }

    fn shutdown(&mut self) {
        {
            let mut st = self.core.state.lock().unwrap();
            st.shutdown = true;
            st.queue.clear();
            st.pending.clear();
            st.waiting.clear();
            // Dropping every scan's sender unblocks any stream still
            // waiting on a delivery — it sees a terminal "server shut
            // down" error instead of hanging.
            st.scans.clear();
        }
        self.core.work_ready.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ScanServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::{Algorithm, Settings};
    use crate::gen::synthetic;
    use crate::rfile::write_tree_serial;

    fn corpus_dir(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("rootio_serve_{}_{}", std::process::id(), name));
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    fn write_file(dir: &Path, name: &str, n: usize, seed: u64) -> Vec<Vec<Value>> {
        let events = synthetic::events(n, seed);
        write_tree_serial(
            &dir.join(format!("{name}.rfil")),
            "Events",
            synthetic::schema(),
            Settings::new(Algorithm::Lz4, 1),
            1024,
            events.iter().cloned(),
        )
        .unwrap();
        events
    }

    fn cfg_small() -> ServeConfig {
        ServeConfig { workers: 2, max_scans: 4, queue_depth: 4, ..ServeConfig::default() }
    }

    #[test]
    fn serial_queries_match_direct_reads() {
        let dir = corpus_dir("serial");
        let events_a = write_file(&dir, "alpha", 300, 0xA);
        let events_b = write_file(&dir, "beta", 200, 0xB);
        let server = ScanServer::open_corpus(&dir, cfg_small()).unwrap();
        assert_eq!(server.files().len(), 2);
        assert_eq!(server.files()[0].name, "alpha");

        // Projection query vs the in-memory truth.
        let mut q = server.query(&Query::project("alpha", &["px", "nTrack"])).unwrap();
        assert!(q.plan().is_monotonic_sweep());
        let cols = q.read_columns().unwrap();
        let px: Vec<Value> = events_a.iter().map(|e| e[3].clone()).collect();
        assert_eq!(cols[0], px);

        // All-branch entry-range query on the other file.
        let mut q2 = server.query(&Query::all("beta").entries(50, 90)).unwrap();
        let mut rows = Vec::new();
        while let Some(batch) = q2.next_batch() {
            let batch = batch.unwrap();
            rows.extend(batch.rows);
        }
        assert_eq!(rows, events_b[50..90].to_vec());

        // Unknown file / branch are clean errors.
        assert!(server.query(&Query::all("gamma")).is_err());
        assert!(server.query(&Query::project("alpha", &["nope"])).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn warm_rescan_serves_from_cache() {
        let dir = corpus_dir("warm");
        let _ = write_file(&dir, "events", 400, 0xC);
        let server = ScanServer::open_corpus(&dir, cfg_small()).unwrap();
        let run = |server: &ScanServer| {
            let mut q = server.query(&Query::project("events", &["px", "Track_pt"])).unwrap();
            q.read_columns().unwrap();
            q.stats()
        };
        let cold = run(&server);
        let baskets = server.metrics_snapshot().baskets;
        assert!(baskets > 0);
        assert_eq!(cold.baskets_decoded, baskets, "cold scan decodes everything");
        assert_eq!(cold.baskets_from_cache, 0);

        let warm = run(&server);
        assert_eq!(server.metrics_snapshot().baskets, baskets, "warm scan decodes nothing new");
        assert_eq!(warm.baskets_decoded, 0);
        assert_eq!(warm.baskets_from_cache, baskets);
        assert!(warm.bytes_from_cache > 0);
        let cs = server.cache_stats();
        assert_eq!(cs.hits + cs.misses, cs.lookups);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn every_backend_serves_identical_columns() {
        use crate::rfile::source::IoBackend;
        let dir = corpus_dir("backends");
        let events = write_file(&dir, "events", 250, 0xE);
        let px: Vec<Value> = events.iter().map(|e| e[3].clone()).collect();
        for backend in IoBackend::all() {
            let cfg = ServeConfig {
                io: IoConfig::for_backend(backend),
                // Cold reads every time: this test is about the I/O path,
                // not the cache.
                cache_bytes: 0,
                ..cfg_small()
            };
            let server = ScanServer::open_corpus(&dir, cfg).unwrap();
            let mut q = server.query(&Query::project("events", &["px"])).unwrap();
            let cols = q.read_columns().unwrap();
            assert_eq!(cols[0], px, "{backend} diverged from the written data");
            let snap = server.metrics_snapshot();
            assert!(snap.io_syscalls > 0, "{backend}: no physical reads counted");
            if backend == IoBackend::Coalesced {
                assert!(
                    snap.io_requests_coalesced > 0,
                    "coalesced backend never served from a merge buffer"
                );
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shutdown_with_live_query_errors_instead_of_hanging() {
        let dir = corpus_dir("shutdown");
        let _ = write_file(&dir, "events", 300, 0xD);
        let mut server = ScanServer::open_corpus(&dir, cfg_small()).unwrap();
        let mut q = server.query(&Query::all("events")).unwrap();
        // Pull one batch, then shut the server down under the live query.
        let first = q.next_batch().unwrap().unwrap();
        assert!(!first.is_empty());
        server.shutdown();
        let mut saw_error = false;
        while let Some(item) = q.next_batch() {
            if let Err(e) = item {
                saw_error = true;
                assert!(e.to_string().contains("scan server shut down"), "{e}");
                break;
            }
        }
        assert!(saw_error, "query over a shut-down server must surface an error");
        std::fs::remove_dir_all(&dir).ok();
    }
}
