//! `rootio` CLI entrypoint — see `rootio help`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match rootio::cli::run(argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
