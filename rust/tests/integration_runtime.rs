// This target is linted by the CI clippy job; it shares the library's
// style-lint policy (see the lint-policy note in rust/src/lib.rs).

#![allow(unknown_lints, clippy::style)]

//! Runtime integration: the XLA-compiled analyzer must agree with the
//! pure-rust mirror (which itself mirrors the python/numpy reference tested
//! in python/tests/test_model.py) — closing the three-way cross-language
//! correctness loop.
//!
//! Requires `make artifacts` to have run (skips with a message otherwise,
//! so `cargo test` works in a fresh checkout; `make test` always builds
//! artifacts first).

use rootio::runtime::analyzer::{analyze_native, bucket_for};
use rootio::runtime::{cpu_client, Analyzer};
use rootio::util::rng::Rng;
use std::path::Path;

fn artifacts_dir() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").leak()
}

trait Leak {
    fn leak(self) -> &'static Path;
}
impl Leak for std::path::PathBuf {
    fn leak(self) -> &'static Path {
        Box::leak(self.into_boxed_path())
    }
}

fn have_artifacts() -> bool {
    artifacts_dir().join("analyzer_4096.hlo.txt").exists()
}

fn workloads() -> Vec<(&'static str, Vec<u8>)> {
    let mut rng = Rng::new(0xA11A);
    let mut v = Vec::new();
    v.push((
        "offsets",
        (1u32..=100_000).flat_map(|i| i.to_be_bytes()).collect::<Vec<u8>>(),
    ));
    v.push(("noise", rng.bytes(300_000)));
    v.push(("zeros", vec![0u8; 50_000]));
    let floats: Vec<u8> = (0..80_000)
        .flat_map(|i| ((i as f32 * 0.01).sin() * 100.0).to_be_bytes())
        .collect();
    v.push(("floats", floats));
    v
}

#[test]
fn xla_analyzer_matches_native_mirror() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    }
    let client = cpu_client().expect("pjrt cpu client");
    let mut analyzer = Analyzer::load(&client, artifacts_dir()).expect("load artifacts");
    for (name, data) in workloads() {
        let got = analyzer.analyze(&data).expect("xla exec");
        let bucket = bucket_for(data.len());
        match (got, bucket) {
            (Some(f), Some(b)) => {
                let want = analyze_native(&data, b).unwrap();
                let pairs = [
                    (f.h_raw, want.h_raw),
                    (f.h_shuffle, want.h_shuffle),
                    (f.h_bitshuffle, want.h_bitshuffle),
                    (f.h_delta, want.h_delta),
                    (f.rep_raw, want.rep_raw),
                    (f.rep_bitshuffle, want.rep_bitshuffle),
                    (f.zero_bitshuffle, want.zero_bitshuffle),
                    (f.rep_shuffle, want.rep_shuffle),
                ];
                for (i, (g, w)) in pairs.iter().enumerate() {
                    assert!(
                        (g - w).abs() < 1e-3 + 0.001 * w.abs(),
                        "{name}: feature {i}: xla {g} vs native {w}"
                    );
                }
            }
            (None, None) => {}
            (g, b) => panic!("{name}: bucket mismatch xla={g:?} native_bucket={b:?}"),
        }
    }
}

#[test]
fn analyzer_rejects_small_baskets() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts/ not built");
        return;
    }
    let client = cpu_client().unwrap();
    let mut analyzer = Analyzer::load(&client, artifacts_dir()).unwrap();
    assert!(analyzer.analyze(&[0u8; 100]).unwrap().is_none());
    assert_eq!(analyzer.min_bucket(), 4096);
}

#[test]
fn repeated_execution_is_stable() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts/ not built");
        return;
    }
    let client = cpu_client().unwrap();
    let mut analyzer = Analyzer::load(&client, artifacts_dir()).unwrap();
    let data: Vec<u8> = (1u32..=50_000).flat_map(|i| i.to_be_bytes()).collect();
    let a = analyzer.analyze(&data).unwrap().unwrap();
    for _ in 0..5 {
        let b = analyzer.analyze(&data).unwrap().unwrap();
        assert_eq!(a, b);
    }
}
