//! Cross-codec property suite: one seeded generator of adversarial payload
//! classes, every codec (through the engine) must round-trip every payload
//! at every level, and documented size bounds must hold.
//!
//! This is the repository's broadest correctness net: ~1000 randomized
//! (payload, setting) cases per run, deterministic by seed.

use rootio::compression::{Algorithm, Engine, Settings, HEADER_LEN, MAX_SPAN};
use rootio::precond::Precond;
use rootio::util::rng::Rng;

/// Payload classes modelled on what ROOT baskets actually contain.
fn gen_payload(rng: &mut Rng, class: usize, n: usize) -> Vec<u8> {
    let mut data = Vec::with_capacity(n);
    match class {
        // Monotone offset arrays (Fig 6 pathology).
        0 => {
            let mut off = rng.below(1000) as u32;
            while data.len() < n {
                off += rng.below(40) as u32;
                data.extend_from_slice(&off.to_be_bytes());
            }
        }
        // Big-endian floats from smooth distributions.
        1 => {
            while data.len() < n {
                let v = rng.gauss(30.0, 15.0) as f32;
                data.extend_from_slice(&v.to_be_bytes());
            }
        }
        // Byte runs.
        2 => {
            while data.len() < n {
                let b = (rng.next_u64() & 0xFF) as u8;
                let run = rng.range(1, 1000);
                data.extend(std::iter::repeat(b).take(run));
            }
        }
        // Text-ish with shared substrings.
        3 => {
            let vocab = [
                &b"Muon_pt"[..], b"Electron_eta", b"Jet_btagDeepB", b"HLT_", b"=true;", b"[0.0,",
            ];
            while data.len() < n {
                data.extend_from_slice(vocab[rng.range(0, vocab.len() - 1)]);
                if rng.chance(0.2) {
                    let extra = rng.bytes(4);
                    data.extend_from_slice(&extra);
                }
            }
        }
        // Pure noise.
        4 => {
            let bytes = rng.bytes(n);
            data.extend_from_slice(&bytes);
        }
        // Sparse (mostly zeros with islands).
        5 => {
            data.resize(n + 64, 0);
            let islands = rng.range(0, 20);
            for _ in 0..islands {
                let at = rng.range(0, n.max(1));
                let len = rng.range(1, 32).min(n + 32 - at);
                let island = rng.bytes(len);
                data[at..at + island.len()].copy_from_slice(&island);
            }
        }
        // Alternating structure (simulates interleaved AoS records).
        _ => {
            let mut i = 0u32;
            while data.len() < n {
                data.extend_from_slice(&i.to_be_bytes());
                data.extend_from_slice(&(rng.f32()).to_be_bytes());
                data.push((i % 3) as u8);
                i += 1;
            }
        }
    }
    data.truncate(n);
    data
}

fn settings_grid(rng: &mut Rng) -> Settings {
    let algs = [
        Algorithm::Zlib,
        Algorithm::CfZlib,
        Algorithm::Lzma,
        Algorithm::OldRoot,
        Algorithm::Lz4,
        Algorithm::Zstd,
        Algorithm::None,
    ];
    let alg = algs[rng.range(0, algs.len() - 1)];
    let level = if alg == Algorithm::None { 0 } else { rng.range(1, 9) as u8 };
    let precond = match rng.range(0, 5) {
        0 => Precond::None,
        1 => Precond::Shuffle([2u8, 4, 8][rng.range(0, 2)]),
        2 => Precond::BitShuffle([1u8, 2, 4, 8][rng.range(0, 3)]),
        3 => Precond::Delta([1u8, 4, 8][rng.range(0, 2)]),
        _ => Precond::None,
    };
    Settings::new(alg, level).with_precond(precond)
}

#[test]
fn everything_roundtrips() {
    let mut rng = Rng::new(0x0707_2026);
    let mut engine = Engine::new();
    let mut cases = 0usize;
    for round in 0..150 {
        let class = round % 7;
        let n = match round % 4 {
            0 => rng.range(0, 64),
            1 => rng.range(64, 4096),
            2 => rng.range(4096, 65_536),
            _ => rng.range(65_536, 300_000),
        };
        let data = gen_payload(&mut rng, class, n);
        for _ in 0..4 {
            let s = settings_grid(&mut rng);
            let c = engine.compress(&data, &s);
            let d = engine
                .decompress(&c)
                .unwrap_or_else(|e| panic!("decompress failed ({}, class {class}, n {n}): {e}", s.label()));
            assert_eq!(d, data, "{} class {class} n {n}", s.label());
            // Documented expansion bound: raw fallback caps overhead at
            // one header per 16 MiB span.
            let spans = data.len() / MAX_SPAN + 1;
            assert!(
                c.len() <= data.len() + spans * HEADER_LEN,
                "{}: {} -> {}",
                s.label(),
                data.len(),
                c.len()
            );
            cases += 1;
        }
    }
    assert!(cases >= 600, "ran {cases} cases");
}

#[test]
fn compressible_classes_actually_compress() {
    // Guard against silently falling back to raw everywhere: on structured
    // classes every real codec must achieve ratio > 1.3 at level >= 5.
    let mut rng = Rng::new(0xBEE5);
    let mut engine = Engine::new();
    for class in [0usize, 2, 3, 5] {
        let data = gen_payload(&mut rng, class, 100_000);
        for alg in [
            Algorithm::Zlib,
            Algorithm::CfZlib,
            Algorithm::Lzma,
            Algorithm::Lz4,
            Algorithm::Zstd,
        ] {
            // Class 0 (offsets) is the known LZ4 weakness: allow it (that is
            // the paper's whole point) but require BitShuffle to fix it.
            let s = if alg == Algorithm::Lz4 && class == 0 {
                Settings::new(alg, 6).with_precond(Precond::BitShuffle(4))
            } else {
                Settings::new(alg, 6)
            };
            let c = engine.compress(&data, &s);
            let ratio = data.len() as f64 / c.len() as f64;
            assert!(
                ratio > 1.3,
                "{} class {class}: ratio {ratio:.3}",
                s.label()
            );
        }
    }
}

#[test]
fn deterministic_compression() {
    // Same input + settings -> identical bytes (required for the pipeline's
    // serial-vs-parallel equivalence guarantee).
    let mut rng = Rng::new(0xDE7E);
    let data = gen_payload(&mut rng, 3, 50_000);
    let mut e1 = Engine::new();
    let mut e2 = Engine::new();
    for alg in Algorithm::survey() {
        let s = Settings::new(alg, 6);
        assert_eq!(e1.compress(&data, &s), e2.compress(&data, &s), "{}", s.label());
        // And stable across reuse of the same engine.
        assert_eq!(e1.compress(&data, &s), e1.compress(&data, &s), "{}", s.label());
    }
}
