// This target is linted by the CI clippy job; it shares the library's
// style-lint policy (see the lint-policy note in rust/src/lib.rs).

#![allow(unknown_lints, clippy::style)]

//! Cross-codec property suite: one seeded generator of adversarial payload
//! classes, every codec (through the engine) must round-trip every payload
//! at every level, and documented size bounds must hold.
//!
//! This is the repository's broadest correctness net: ~1000 randomized
//! (payload, setting) cases per run, deterministic by seed.

use rootio::compression::{Algorithm, Engine, Settings, HEADER_LEN, MAX_SPAN};
use rootio::precond::Precond;
use rootio::util::rng::Rng;

/// Payload classes modelled on what ROOT baskets actually contain.
fn gen_payload(rng: &mut Rng, class: usize, n: usize) -> Vec<u8> {
    let mut data = Vec::with_capacity(n);
    match class {
        // Monotone offset arrays (Fig 6 pathology).
        0 => {
            let mut off = rng.below(1000) as u32;
            while data.len() < n {
                off += rng.below(40) as u32;
                data.extend_from_slice(&off.to_be_bytes());
            }
        }
        // Big-endian floats from smooth distributions.
        1 => {
            while data.len() < n {
                let v = rng.gauss(30.0, 15.0) as f32;
                data.extend_from_slice(&v.to_be_bytes());
            }
        }
        // Byte runs.
        2 => {
            while data.len() < n {
                let b = (rng.next_u64() & 0xFF) as u8;
                let run = rng.range(1, 1000);
                data.extend(std::iter::repeat(b).take(run));
            }
        }
        // Text-ish with shared substrings.
        3 => {
            let vocab = [
                &b"Muon_pt"[..], b"Electron_eta", b"Jet_btagDeepB", b"HLT_", b"=true;", b"[0.0,",
            ];
            while data.len() < n {
                data.extend_from_slice(vocab[rng.range(0, vocab.len() - 1)]);
                if rng.chance(0.2) {
                    let extra = rng.bytes(4);
                    data.extend_from_slice(&extra);
                }
            }
        }
        // Pure noise.
        4 => {
            let bytes = rng.bytes(n);
            data.extend_from_slice(&bytes);
        }
        // Sparse (mostly zeros with islands).
        5 => {
            data.resize(n + 64, 0);
            let islands = rng.range(0, 20);
            for _ in 0..islands {
                let at = rng.range(0, n.max(1));
                let len = rng.range(1, 32).min(n + 32 - at);
                let island = rng.bytes(len);
                data[at..at + island.len()].copy_from_slice(&island);
            }
        }
        // Alternating structure (simulates interleaved AoS records).
        _ => {
            let mut i = 0u32;
            while data.len() < n {
                data.extend_from_slice(&i.to_be_bytes());
                data.extend_from_slice(&(rng.f32()).to_be_bytes());
                data.push((i % 3) as u8);
                i += 1;
            }
        }
    }
    data.truncate(n);
    data
}

fn settings_grid(rng: &mut Rng) -> Settings {
    let algs = [
        Algorithm::Zlib,
        Algorithm::CfZlib,
        Algorithm::Lzma,
        Algorithm::OldRoot,
        Algorithm::Lz4,
        Algorithm::Zstd,
        Algorithm::None,
    ];
    let alg = algs[rng.range(0, algs.len() - 1)];
    let level = if alg == Algorithm::None { 0 } else { rng.range(1, 9) as u8 };
    let precond = match rng.range(0, 5) {
        0 => Precond::None,
        1 => Precond::Shuffle([2u8, 4, 8][rng.range(0, 2)]),
        2 => Precond::BitShuffle([1u8, 2, 4, 8][rng.range(0, 3)]),
        3 => Precond::Delta([1u8, 4, 8][rng.range(0, 2)]),
        _ => Precond::None,
    };
    Settings::new(alg, level).with_precond(precond)
}

#[test]
fn everything_roundtrips() {
    let mut rng = Rng::new(0x0707_2026);
    let mut engine = Engine::new();
    let mut cases = 0usize;
    for round in 0..150 {
        let class = round % 7;
        let n = match round % 4 {
            0 => rng.range(0, 64),
            1 => rng.range(64, 4096),
            2 => rng.range(4096, 65_536),
            _ => rng.range(65_536, 300_000),
        };
        let data = gen_payload(&mut rng, class, n);
        for _ in 0..4 {
            let s = settings_grid(&mut rng);
            let c = engine.compress(&data, &s);
            let d = engine
                .decompress(&c)
                .unwrap_or_else(|e| panic!("decompress failed ({}, class {class}, n {n}): {e}", s.label()));
            assert_eq!(d, data, "{} class {class} n {n}", s.label());
            // Documented expansion bound: raw fallback caps overhead at
            // one header per 16 MiB span.
            let spans = data.len() / MAX_SPAN + 1;
            assert!(
                c.len() <= data.len() + spans * HEADER_LEN,
                "{}: {} -> {}",
                s.label(),
                data.len(),
                c.len()
            );
            cases += 1;
        }
    }
    assert!(cases >= 600, "ran {cases} cases");
}

#[test]
fn compressible_classes_actually_compress() {
    // Guard against silently falling back to raw everywhere: on structured
    // classes every real codec must achieve ratio > 1.3 at level >= 5.
    let mut rng = Rng::new(0xBEE5);
    let mut engine = Engine::new();
    for class in [0usize, 2, 3, 5] {
        let data = gen_payload(&mut rng, class, 100_000);
        for alg in [
            Algorithm::Zlib,
            Algorithm::CfZlib,
            Algorithm::Lzma,
            Algorithm::Lz4,
            Algorithm::Zstd,
        ] {
            // Class 0 (offsets) is the known LZ4 weakness: allow it (that is
            // the paper's whole point) but require BitShuffle to fix it.
            let s = if alg == Algorithm::Lz4 && class == 0 {
                Settings::new(alg, 6).with_precond(Precond::BitShuffle(4))
            } else {
                Settings::new(alg, 6)
            };
            let c = engine.compress(&data, &s);
            let ratio = data.len() as f64 / c.len() as f64;
            assert!(
                ratio > 1.3,
                "{} class {class}: ratio {ratio:.3}",
                s.label()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Fast-path vs naive-reference equivalence (§Perf): every optimized hot loop
// must be BIT-IDENTICAL to its scalar/naive oracle across the fuzz corpus.
// ---------------------------------------------------------------------------

#[test]
fn match_len_fast_equals_naive() {
    use rootio::deflate::matcher::{match_len, reference::match_len_naive};
    let mut rng = Rng::new(0x11_2233);
    for round in 0..400 {
        let n = rng.range(2, 5000);
        // Low-entropy bytes so long common prefixes actually occur.
        let data: Vec<u8> = (0..n).map(|_| (rng.next_u64() & 0x3) as u8).collect();
        let b = rng.range(1, n - 1);
        let a = rng.range(0, b - 1);
        let cap = rng.range(0, 300);
        assert_eq!(
            match_len(&data, a, b, cap),
            match_len_naive(&data, a, b, cap),
            "round {round}: a={a} b={b} cap={cap}"
        );
    }
    // Deterministic worst cases: identical suffixes, cap boundaries at the
    // 8-byte compare width.
    let data = vec![7u8; 600];
    for cap in [0usize, 1, 7, 8, 9, 15, 16, 17, 258, 600] {
        assert_eq!(match_len(&data, 0, 100, cap), match_len_naive(&data, 0, 100, cap));
    }
}

#[test]
fn bitshuffle_swar_equals_naive_on_fuzz_corpus() {
    use rootio::precond::bitshuffle::{bitshuffle, reference, unbitshuffle};
    let mut rng = Rng::new(0x44_5566);
    for round in 0..120 {
        let class = round % 7;
        let n = rng.range(0, 20_000);
        let data = gen_payload(&mut rng, class, n);
        for stride in [1usize, 2, 3, 4, 5, 8] {
            let fast = bitshuffle(&data, stride);
            assert_eq!(
                fast,
                reference::bitshuffle_naive(&data, stride),
                "class {class} n {n} stride {stride}"
            );
            assert_eq!(
                unbitshuffle(&fast, stride),
                reference::unbitshuffle_naive(&fast, stride),
                "inv class {class} n {n} stride {stride}"
            );
        }
    }
}

#[test]
fn shuffle_specializations_equal_generic_on_fuzz_corpus() {
    use rootio::precond::shuffle::{reference, shuffle, unshuffle};
    let mut rng = Rng::new(0x55_6677);
    for round in 0..120 {
        let class = round % 7;
        let n = rng.range(0, 20_000);
        let data = gen_payload(&mut rng, class, n);
        for stride in [2usize, 4, 8] {
            let fast = shuffle(&data, stride);
            assert_eq!(fast, reference::shuffle_naive(&data, stride), "class {class} n {n} stride {stride}");
            assert_eq!(
                unshuffle(&fast, stride),
                reference::unshuffle_naive(&fast, stride),
                "inv class {class} n {n} stride {stride}"
            );
        }
    }
}

#[test]
fn fused_huffman_emission_equals_reference_on_fuzz_corpus() {
    use rootio::deflate::compress::{deflate, deflate_reference};
    use rootio::deflate::{Flavor, Tuning};
    let mut rng = Rng::new(0x66_7788);
    for round in 0..60 {
        let class = round % 7;
        let n = rng.range(0, 60_000);
        let data = gen_payload(&mut rng, class, n);
        let flavor = if round % 2 == 0 { Flavor::Reference } else { Flavor::Cloudflare };
        let level = [1u8, 4, 6, 9][round % 4];
        let t = Tuning::new(flavor, level);
        assert_eq!(
            deflate(&data, &t),
            deflate_reference(&data, &t),
            "{} class {class} n {n}",
            t.label()
        );
    }
}

#[test]
fn lz4_decode_wildcopy_equals_naive_on_fuzz_corpus() {
    // PR-2 tentpole: the wild-copy slice decoder must return exactly the
    // bytes of the Vec-growth naive decoder for every stream either
    // accepts, and agree on rejection otherwise — across compressor
    // variants, payload classes, dictionary prefixes, truncations and
    // random corruption.
    use rootio::lz4::decode::{decompress_block_dict_into, reference::decompress_block_naive};
    use rootio::lz4::{Lz4Fast, Lz4Hc};
    let mut rng = Rng::new(0x4C5A);
    let mut fast_c = Lz4Fast::new();
    let mut hc = Lz4Hc::new();
    let mut blk = Vec::new();
    let mut out = Vec::new();
    for round in 0..120 {
        let class = round % 7;
        let n = rng.range(0, 30_000);
        let data = gen_payload(&mut rng, class, n);
        let dict = if round % 4 == 0 { rng.bytes(rng.range(1, 600)) } else { Vec::new() };
        if dict.is_empty() && round % 2 == 1 {
            hc.compress(&data, [3u8, 9, 12][round % 3], &mut blk);
        } else if dict.is_empty() {
            fast_c.compress(&data, 1 + (round % 5) as u32, &mut blk);
        } else {
            let mut buf = dict.clone();
            buf.extend_from_slice(&data);
            fast_c.compress_dict(&buf, dict.len(), 1, &mut blk);
        }
        // Valid stream: identical bytes.
        decompress_block_dict_into(&blk, &dict, data.len(), &mut out)
            .unwrap_or_else(|e| panic!("class {class} n {n}: {e}"));
        assert_eq!(out, data, "class {class} n {n} dict {}", dict.len());
        let naive = decompress_block_naive(&blk, &dict, data.len()).expect("naive decode");
        assert_eq!(naive, data, "naive disagrees: class {class} n {n}");
        // Truncations: both reject (or both accept with identical bytes —
        // possible when the cut lands on a sequence boundary by luck).
        for cut in [0usize, blk.len() / 3, blk.len().saturating_sub(1)] {
            let fast = {
                let r = decompress_block_dict_into(&blk[..cut], &dict, data.len(), &mut out);
                r.map(|_| out.clone())
            };
            let nv = decompress_block_naive(&blk[..cut], &dict, data.len());
            match (fast, nv) {
                (Ok(a), Ok(b)) => assert_eq!(a, b, "cut {cut}"),
                (Err(_), Err(_)) => {}
                (f, v) => panic!("cut {cut}: fast {:?} vs naive {:?}", f.is_ok(), v.is_ok()),
            }
        }
        // Random corruption: never panic, agree on accept/reject; on
        // accept-with-wrong-length semantics both still enforce size.
        if !blk.is_empty() {
            let mut bad = blk.clone();
            let at = rng.range(0, bad.len() - 1);
            bad[at] ^= 1 << (round % 8);
            let fast = {
                let r = decompress_block_dict_into(&bad, &dict, data.len(), &mut out);
                r.map(|_| out.clone())
            };
            let nv = decompress_block_naive(&bad, &dict, data.len());
            match (fast, nv) {
                (Ok(a), Ok(b)) => assert_eq!(a, b, "corrupt at {at}"),
                (Err(_), Err(_)) => {}
                (f, v) => panic!("corrupt at {at}: fast {:?} vs naive {:?}", f.is_ok(), v.is_ok()),
            }
        }
    }
}

#[test]
fn lz4_decode_edge_case_table() {
    // Satellite: deterministic adversarial streams — offset < 8 overlap
    // copies, matches reaching into the dictionary prefix, malformed
    // tokens. Fast and naive must agree everywhere; nothing may panic.
    use rootio::lz4::decode::{
        decompress_block, decompress_block_dict_into, reference::decompress_block_naive,
    };
    // (stream, dict, expected_len) table.
    let dict: Vec<u8> = (0..64u8).collect();
    let mut table: Vec<(Vec<u8>, Vec<u8>, usize)> = Vec::new();
    // Overlap offsets 1..8 with lengths crossing the 8-byte wild stride.
    for offset in 1usize..8 {
        for ml in [4usize, 7, 8, 9, 19] {
            let lits: Vec<u8> = (0..offset as u8).map(|k| k + 1).collect();
            let mut s = vec![((offset as u8) << 4) | ((ml - 4).min(15) as u8)];
            s.extend_from_slice(&lits);
            s.extend_from_slice(&(offset as u16).to_le_bytes());
            if ml - 4 >= 15 {
                s.push((ml - 4 - 15) as u8);
            }
            s.push(0);
            table.push((s, Vec::new(), offset + ml));
        }
    }
    // Match reaching entirely into the dictionary prefix: zero literals,
    // offset spanning back into the dict.
    for offset in [1usize, 7, 30, 64] {
        let ml = 8usize;
        let mut s = vec![(ml - 4) as u8]; // no literals, match only
        s.extend_from_slice(&(offset as u16).to_le_bytes());
        s.push(0);
        table.push((s, dict.clone(), ml));
    }
    // Malformed: offset one past the dictionary, huge lengths, truncated
    // extension bytes.
    table.push((vec![0x00, 65, 0, 0x00], dict.clone(), 4)); // offset 65 > dict 64
    table.push((vec![0x0F, 255, 255], Vec::new(), 100)); // truncated match ext
    table.push((vec![0xF0, 255], Vec::new(), 100)); // truncated literal ext
    table.push((vec![0x1F, b'x', 1, 0, 255, 255, 255, 10], Vec::new(), 50)); // match overflows expected
    let mut out = Vec::new();
    for (k, (stream, d, n)) in table.iter().enumerate() {
        let fast = {
            let r = decompress_block_dict_into(stream, d, *n, &mut out);
            r.map(|_| out.clone())
        };
        let naive = decompress_block_naive(stream, d, *n);
        match (&fast, &naive) {
            (Ok(a), Ok(b)) => assert_eq!(a, b, "case {k}"),
            (Err(_), Err(_)) => {}
            _ => panic!("case {k}: fast {:?} vs naive {:?}", fast.is_ok(), naive.is_ok()),
        }
        // Dict-free convenience wrapper must agree too.
        if d.is_empty() {
            let w = decompress_block(stream, *n);
            assert_eq!(w.is_ok(), naive.is_ok(), "case {k} wrapper");
        }
    }
}

#[test]
fn fse_interleaved_fast_equals_naive_on_fuzz_corpus() {
    use rootio::util::bitio::BitReader;
    use rootio::zstd::fse;
    let mut rng = Rng::new(0x88_99AA);
    for round in 0..60 {
        let class = round % 7;
        let n = rng.range(2, 30_000);
        let data = gen_payload(&mut rng, class, n);
        let hist = fse::histogram(&data);
        assert_eq!(hist, fse::reference::histogram_naive(&data), "histogram class {class} n {n}");
        let present = hist.iter().filter(|&&c| c > 0).count();
        if present < 2 {
            continue;
        }
        let log = fse::optimal_table_log(data.len(), present, 11);
        let norm = fse::normalize_counts(&hist, data.len() as u64, log).unwrap();
        let enc = fse::EncTable::new(&norm, log).unwrap();
        let dec = fse::DecTable::new(&norm, log).unwrap();
        let syms: Vec<u16> = data.iter().map(|&b| b as u16).collect();
        // Encoders: byte-identical payload and states.
        let (fast_payload, fast_states) = enc.encode_interleaved(&data[..]);
        let (naive_payload, naive_states) = fse::reference::encode_interleaved_naive(&enc, &syms);
        assert_eq!(fast_payload, naive_payload, "class {class} n {n}");
        assert_eq!(fast_states, naive_states, "class {class} n {n}");
        // Decoders: identical symbols.
        let mut a = Vec::new();
        dec.decode_interleaved(&mut BitReader::new(&fast_payload), fast_states, n, &mut a)
            .unwrap();
        let mut b = Vec::new();
        fse::reference::decode_interleaved_naive(
            &dec,
            &mut BitReader::new(&fast_payload),
            fast_states,
            n,
            &mut b,
        )
        .unwrap();
        assert_eq!(a, b, "class {class} n {n}");
        assert_eq!(a, syms, "roundtrip class {class} n {n}");
        // Truncation: both reject.
        if fast_payload.len() > 1 {
            let cut = &fast_payload[..fast_payload.len() / 2];
            let mut t = Vec::new();
            assert!(dec
                .decode_interleaved(&mut BitReader::new(cut), fast_states, n, &mut t)
                .is_err());
            let mut t2 = Vec::new();
            assert!(fse::reference::decode_interleaved_naive(
                &dec,
                &mut BitReader::new(cut),
                fast_states,
                n,
                &mut t2
            )
            .is_err());
        }
    }
}

// NOTE: common_prefix fast-vs-naive equality is covered by the unit test
// in util/match_finder.rs (common_prefix_fast_equals_naive); the deflate
// `match_len` wrapper over it keeps its own oracle test above.

#[test]
fn inflate_fast_equals_careful_reference() {
    use rootio::deflate::compress::deflate;
    use rootio::deflate::inflate::{inflate, inflate_reference};
    use rootio::deflate::{Flavor, Tuning};
    let mut rng = Rng::new(0xAA_BBCC);
    const MAX: usize = 64 << 20;
    for round in 0..40 {
        let class = round % 7;
        let n = rng.range(0, 60_000);
        let data = gen_payload(&mut rng, class, n);
        let t = Tuning::new(
            if round % 2 == 0 { Flavor::Reference } else { Flavor::Cloudflare },
            [1u8, 4, 6, 9][round % 4],
        );
        let c = deflate(&data, &t);
        // Bit-identity: batched-literal fast loop vs careful-only decode.
        let fast = inflate(&c, data.len(), MAX).expect("fast inflate");
        let careful = inflate_reference(&c, data.len(), MAX).expect("careful inflate");
        assert_eq!(fast, careful, "{} class {class} n {n}", t.label());
        assert_eq!(fast, data, "roundtrip {} class {class} n {n}", t.label());
        // Truncations must be rejected by both.
        if c.len() > 2 {
            for cut in [c.len() / 2, c.len() - 1] {
                assert!(inflate(&c[..cut], data.len(), MAX).is_err(), "fast cut {cut}");
                assert!(inflate_reference(&c[..cut], data.len(), MAX).is_err(), "careful cut {cut}");
            }
        }
    }
}

#[test]
fn bitwriter_word_flush_equals_naive() {
    use rootio::util::bitio::{reference::NaiveBitWriter, BitWriter};
    let mut rng = Rng::new(0x77_8899);
    for _ in 0..200 {
        let mut fast = BitWriter::new();
        let mut naive = NaiveBitWriter::new();
        for _ in 0..rng.range(1, 600) {
            if rng.chance(0.08) {
                fast.align_byte();
                naive.align_byte();
                continue;
            }
            let width = rng.range(1, 57) as u32;
            let val = rng.next_u64() & ((1u64 << width) - 1);
            fast.write_bits(val, width);
            naive.write_bits(val, width);
        }
        assert_eq!(fast.finish(), naive.finish());
    }
}

#[test]
fn deterministic_compression() {
    // Same input + settings -> identical bytes (required for the pipeline's
    // serial-vs-parallel equivalence guarantee).
    let mut rng = Rng::new(0xDE7E);
    let data = gen_payload(&mut rng, 3, 50_000);
    let mut e1 = Engine::new();
    let mut e2 = Engine::new();
    for alg in Algorithm::survey() {
        let s = Settings::new(alg, 6);
        assert_eq!(e1.compress(&data, &s), e2.compress(&data, &s), "{}", s.label());
        // And stable across reuse of the same engine.
        assert_eq!(e1.compress(&data, &s), e1.compress(&data, &s), "{}", s.label());
    }
}
