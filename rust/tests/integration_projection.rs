// This target is linted by the CI clippy job; it shares the library's
// style-lint policy (see the lint-policy note in rust/src/lib.rs).

#![allow(unknown_lints, clippy::style)]

//! Columnar projection invariants (property-style, seeded): a k-of-n
//! branch projection through [`rootio::coordinator::ProjectionReader`]
//! must be **byte-identical** to k independent serial
//! [`rootio::rfile::TreeReader::read_branch`] calls — for any worker
//! count (1/2/4), queue depth, codec × preconditioner, and either
//! prefetch order — and must agree with the serial reader on *rejection*
//! when a projected branch's basket is corrupted. A corrupted basket in
//! an **unprojected** branch must not affect the projection at all:
//! that's the columnar contract (untouched branches are never read).
//!
//! Fixtures come from the shared testkit (`mod common`): `PROP_SEED`
//! reproduces a failed run, `PROP_ROUNDS` caps the grid (see
//! rust/tests/common/mod.rs).

mod common;

use common::{grid, prop_rounds, sample, seeded, tmp_path, write_sample_tree};
use rootio::compression::{Algorithm, Settings};
use rootio::coordinator::{
    ParallelTreeReader, PrefetchOrder, ProjectionPlan, ReadAhead,
};
use rootio::gen::synthetic;
use rootio::precond::Precond;
use rootio::rfile::{write_tree_serial, TreeReader, Value};

#[test]
fn k_of_n_projection_equals_serial_read_branch_across_grid() {
    let (mut rng, _guard) = seeded(0x9207);
    let events_seed = rng.next_u64();
    let events = synthetic::events(150, events_seed);
    let n_branches = synthetic::schema().len() as u32;
    let settings_grid = sample(grid(), prop_rounds(usize::MAX));
    for (i, settings) in settings_grid.into_iter().enumerate() {
        let basket_size = rng.range(256, 8192);
        let path = tmp_path("proj_prop", &format!("grid{i}"));
        write_tree_serial(
            &path,
            "Events",
            synthetic::schema(),
            settings,
            basket_size,
            events.iter().cloned(),
        )
        .unwrap();

        // Rotate the projected subset per setting: k in 1..=4, stride-3
        // indices are distinct mod 12.
        let k = 1 + (i % 4);
        let ids: Vec<u32> = (0..k).map(|j| ((i + 3 * j) as u32) % n_branches).collect();

        // Serial oracle columns.
        let mut serial = TreeReader::open(&path).unwrap();
        let oracle: Vec<Vec<Value>> =
            ids.iter().map(|&id| serial.read_branch(id).unwrap()).collect();

        // Alternate the prefetch order across the grid; results must not
        // depend on it.
        let order = if i % 2 == 0 { PrefetchOrder::FileOffset } else { PrefetchOrder::Submission };
        for workers in [1usize, 2, 4] {
            let depth = rng.range(1, 8);
            let par = ParallelTreeReader::open(&path, ReadAhead { workers, depth }).unwrap();
            let plan = ProjectionPlan::new(&par.meta, &ids, order).unwrap();
            if order == PrefetchOrder::FileOffset {
                assert!(
                    plan.is_monotonic_sweep(),
                    "{} offset plan must be one forward sweep",
                    settings.label()
                );
            }
            let mut proj = par.project_plan(&plan).unwrap();
            let columns = proj.read_columns().unwrap();
            assert_eq!(
                columns,
                oracle,
                "{} w={workers} d={depth} ids={ids:?} {order:?}",
                settings.label()
            );
        }
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn name_level_apis_match_serial() {
    let path = tmp_path("proj_prop", "names");
    write_sample_tree(
        &path,
        Settings::new(Algorithm::Zstd, 5).with_precond(Precond::Shuffle(4)),
        400,
        2048,
        0xAB5,
    );
    let mut serial = TreeReader::open(&path).unwrap();
    let names = ["Track_pt", "px", "label"];
    let oracle: Vec<Vec<Value>> = names
        .iter()
        .map(|n| serial.read_branch(serial.branch_id(n).unwrap()).unwrap())
        .collect();
    // ParallelTreeReader::read_branches (one-call columns).
    let par = ParallelTreeReader::open(&path, ReadAhead::with_workers(3)).unwrap();
    assert_eq!(par.read_branches(&names).unwrap(), oracle);
    // TreeReader::project (serial reader upgrade path).
    let mut proj = serial.project(&names, ReadAhead::with_workers(2)).unwrap();
    assert_eq!(proj.read_columns().unwrap(), oracle);
    std::fs::remove_file(&path).ok();
}

#[test]
fn corrupted_projected_basket_rejected_in_parity_and_skipped_when_unprojected() {
    let path = tmp_path("proj_prop", "corrupt");
    // BitShuffle makes the jagged float branch LZ4-compressible (the Fig-6
    // rescue), so its spans carry the "L4" tag + CRC-32 rather than the
    // checksum-less raw-store fallback.
    write_sample_tree(
        &path,
        Settings::new(Algorithm::Lz4, 1).with_precond(Precond::BitShuffle(4)),
        300,
        1024,
        0xD0C,
    );

    // Corrupt the *stored CRC-32* of an LZ4 span in one Track_pt basket:
    // the decoded bytes are untouched, so only checksum verification can
    // catch it — both readers must reject (same technique as the
    // read-pipeline checksum parity test; framing per docs/FORMAT.md §5–6).
    let serial = TreeReader::open(&path).unwrap();
    let victim_id = serial.branch_id("Track_pt").unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    let mut patched = false;
    for loc in serial.baskets_for(victim_id) {
        // Record layout at loc.file_offset: u32 len, u8 kind, payload.
        let payload_start = loc.file_offset as usize + 5;
        let payload = &bytes[payload_start..payload_start + loc.compressed_len as usize];
        // Five uvarints (branch_id, basket_index, n_entries, data_len,
        // n_offsets) precede the first span header.
        let mut pos = 0usize;
        for _ in 0..5 {
            let (_, n) = rootio::util::varint::get_uvarint(&payload[pos..]).unwrap();
            pos += n;
        }
        // Span header: 2-byte tag, level, 3+3-byte sizes, precond byte;
        // the LZ4 CRC-32 is the first 4 bytes of the span body.
        if payload.get(pos..pos + 2) == Some(b"L4") {
            bytes[payload_start + pos + 10] ^= 0xFF;
            patched = true;
            break;
        }
    }
    assert!(patched, "no LZ4-compressed Track_pt span found to patch");
    let bad_path = tmp_path("proj_prop", "corrupt_flipped");
    std::fs::write(&bad_path, &bytes).unwrap();

    // Serial oracle: the corrupted branch is rejected, others still read.
    let mut serial = TreeReader::open(&bad_path).unwrap();
    assert!(serial.read_branch(victim_id).is_err(), "serial accepted the corrupted basket");
    let clean_oracle: Vec<Vec<Value>> = ["px", "event_id"]
        .iter()
        .map(|n| serial.read_branch(serial.branch_id(n).unwrap()).unwrap())
        .collect();

    for workers in [1usize, 2, 4] {
        let par = ParallelTreeReader::open(&bad_path, ReadAhead::with_workers(workers)).unwrap();
        // Projection that includes the corrupted branch: rejected, in
        // parity with the serial reader.
        let err = par.read_branches(&["px", "Track_pt"]);
        assert!(err.is_err(), "w={workers}: projection accepted a corrupted projected basket");
        // Projection that skips it: unaffected — the corrupted basket is
        // never read, decoded, or checksummed.
        assert_eq!(
            par.read_branches(&["px", "event_id"]).unwrap(),
            clean_oracle,
            "w={workers}: projection without the corrupted branch must succeed"
        );
    }

    // Errors are terminal on the batch iterator: after the first Err, the
    // stream ends (None) instead of emitting rows misaligned by the lost
    // basket, and read_columns refuses the failed projection too.
    let par = ParallelTreeReader::open(&bad_path, ReadAhead::with_workers(2)).unwrap();
    let mut proj = par.project(&["px", "Track_pt"]).unwrap();
    let mut saw_err = false;
    while let Some(batch) = proj.next_batch() {
        if batch.is_err() {
            saw_err = true;
            break;
        }
    }
    assert!(saw_err, "batch iterator never surfaced the corruption");
    assert!(proj.next_batch().is_none(), "error must be terminal");
    assert!(proj.read_columns().is_err(), "failed projection must not drain");
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&bad_path).ok();
}

#[test]
fn row_batches_zip_the_same_values() {
    let path = tmp_path("proj_prop", "rows");
    write_sample_tree(
        &path,
        Settings::new(Algorithm::Lz4, 1).with_precond(Precond::BitShuffle(4)),
        250,
        1024,
        0x3A7,
    );
    let mut serial = TreeReader::open(&path).unwrap();
    let names = ["nTrack", "Track_charge", "is_good"];
    let cols: Vec<Vec<Value>> = names
        .iter()
        .map(|n| serial.read_branch(serial.branch_id(n).unwrap()).unwrap())
        .collect();
    let par = ParallelTreeReader::open(&path, ReadAhead::with_workers(2)).unwrap();
    let mut proj = par.project(&names).unwrap();
    let mut entry = 0usize;
    while let Some(batch) = proj.next_batch() {
        let batch = batch.unwrap();
        assert_eq!(batch.first_entry, entry as u64);
        for row in &batch.rows {
            for (slot, v) in row.iter().enumerate() {
                assert_eq!(*v, cols[slot][entry], "entry {entry} slot {slot}");
            }
            entry += 1;
        }
    }
    assert_eq!(entry as u64, serial.meta.n_entries);
    std::fs::remove_file(&path).ok();
}
