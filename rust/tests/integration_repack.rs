//! Property suite for `rootio repack` (`coordinator::repack`): the
//! profile-driven rewriter must be an *exact* transformation — whatever
//! the recorded profile says, the output file is event-for-event
//! identical to the source — while re-chunked directories keep every
//! invariant the readers rely on, dictionaries round-trip, and damaged
//! inputs fail strict / degrade honestly under salvage.
//!
//! Runs on the shared testkit: `PROP_SEED=0x…` reproduces a failure,
//! `PROP_ROUNDS=n` caps the grid sample (see `common/mod.rs`).

mod common;

use rootio::compression::{Algorithm, Settings};
use rootio::coordinator::repack::{plan_branches, repack_file, RepackOptions};
use rootio::coordinator::{BranchReadStats, ParallelTreeReader, ReadAhead, UseCase};
use rootio::gen::synthetic;
use rootio::rfile::{TreeReader, Value};
use rootio::runtime::ReadFeedback;

/// Flip one byte in the record header varints of `victim` — deterministic
/// frame-level damage that every codec lane detects (same technique as
/// the read-pipeline salvage suite).
fn corrupt_basket(path: &std::path::Path, file_offset: u64) {
    let mut bytes = std::fs::read(path).unwrap();
    bytes[file_offset as usize + 5] ^= 0x3F;
    std::fs::write(path, bytes).unwrap();
}

/// The tentpole oracle: repack across the codec × preconditioner grid
/// under *random* recorded profiles (random hot subsets, scan counts,
/// generation decay, use cases, and basket overrides) and demand the
/// output reads event-for-event identical — full scans and random entry
/// windows, serial and parallel readers.
#[test]
fn repack_is_event_identical_across_grid_with_random_profiles() {
    let (mut rng, _guard) = common::seeded(0x9e0c_11aa_2026_0808);
    let settings = common::sample(common::grid(), common::prop_rounds(10));
    let n_events = 300usize;
    for (i, s) in settings.iter().enumerate() {
        let src = common::tmp_path("repack", &format!("grid_src_{i}"));
        let dst = common::tmp_path("repack", &format!("grid_dst_{i}"));
        let seed = rng.next_u64();
        let meta = common::write_sample_tree(&src, *s, n_events, 1024, seed);
        let events = synthetic::events(n_events, seed);

        // A random access profile: some branches hot, some cold, recorded
        // over a few (possibly decayed) scans.
        let mut profile = ReadFeedback::new();
        for _ in 0..rng.range(1, 3) {
            let mut stats = Vec::new();
            for (b, def) in meta.branches.iter().enumerate() {
                if !rng.chance(0.6) {
                    continue;
                }
                let stored: u64 = meta
                    .baskets
                    .iter()
                    .filter(|l| l.branch_id == b as u32)
                    .map(|l| l.uncompressed_len as u64)
                    .sum();
                stats.push(BranchReadStats {
                    branch_id: b as u32,
                    name: def.name.clone(),
                    baskets: rng.range(1, 6) as u64,
                    entries: rng.range(1, n_events) as u64,
                    logical_bytes: (stored as f64 * rng.f64() * 1.5) as u64,
                    compressed_bytes: 1 + rng.below(10_000),
                    ..BranchReadStats::default()
                });
            }
            profile.record_scan(&stats);
            if rng.chance(0.3) {
                profile.advance_generation();
            }
        }

        let mut opts = RepackOptions {
            profile: Some(profile),
            workers: 1 + rng.below(3) as usize,
            ..RepackOptions::default()
        };
        opts.use_case = [UseCase::Analysis, UseCase::Balanced, UseCase::Production]
            [rng.below(3) as usize];
        if rng.chance(0.25) {
            opts.target_basket_bytes = Some(1usize << (10 + rng.below(4)));
        }

        let report = repack_file(&src, &dst, &opts).unwrap();
        assert_eq!(report.n_entries_in, n_events as u64, "under {s:?}");
        assert_eq!(report.n_entries_out, n_events as u64, "under {s:?}");
        assert!(report.gaps.is_empty() && report.damage.is_empty());

        let mut serial = TreeReader::open(&dst).unwrap();
        assert_eq!(serial.read_all_events().unwrap(), events, "serial read under {s:?}");

        let par = ParallelTreeReader::open(&dst, ReadAhead::with_workers(2)).unwrap();
        assert_eq!(par.read_all_events().unwrap(), events, "parallel read under {s:?}");
        assert!(
            par.meta.branches.iter().all(|d| d.settings.is_some()),
            "repack stamps planned settings on every branch"
        );

        // Random entry windows decode identically from the re-chunked file.
        for _ in 0..3 {
            let lo = rng.below(n_events as u64 + 1);
            let hi = lo + rng.below(n_events as u64 - lo + 1);
            let got = par.read_all_events_range(lo..hi).unwrap();
            assert_eq!(
                got,
                events[lo as usize..hi as usize].to_vec(),
                "window {lo}..{hi} under {s:?}"
            );
        }
        std::fs::remove_file(&src).ok();
        std::fs::remove_file(&dst).ok();
    }
}

/// Re-chunking must preserve every directory invariant the readers
/// assume: spans contiguous from 0 per branch, `(branch_id,
/// basket_index)` sort order, and strictly increasing file offsets (so
/// an offset-sorted projection plan over the output is one monotonic
/// sweep). A forced `--target-basket-kb` style override must be hit by
/// every basket except each branch's last.
#[test]
fn repack_rechunks_with_contiguous_spans_and_monotonic_sweep() {
    let src = common::tmp_path("repack", "chunk_src");
    let dst = common::tmp_path("repack", "chunk_dst");
    let n_events = 600usize;
    let seed = 0x51ab;
    common::write_sample_tree(&src, Settings::new(Algorithm::Zstd, 5), n_events, 512, seed);

    let target = 8 * 1024usize;
    let opts = RepackOptions {
        target_basket_bytes: Some(target),
        ..RepackOptions::default()
    };
    let report = repack_file(&src, &dst, &opts).unwrap();
    assert!(
        report.baskets_out < report.baskets_in,
        "coalescing 512-byte baskets toward 8 KiB must shrink the directory \
         ({} -> {})",
        report.baskets_in,
        report.baskets_out
    );

    let out = ParallelTreeReader::open(&dst, ReadAhead::with_workers(2)).unwrap();
    let meta = &out.meta;
    assert_eq!(meta.baskets.len(), report.baskets_out);
    for w in meta.baskets.windows(2) {
        assert!(
            (w[0].branch_id, w[0].basket_index) < (w[1].branch_id, w[1].basket_index),
            "directory must stay sorted by (branch_id, basket_index)"
        );
        assert!(
            w[0].file_offset < w[1].file_offset,
            "branch-major directory order must be file order (monotonic sweep)"
        );
    }
    for b in 0..meta.branches.len() as u32 {
        let locs = out.baskets_for(b);
        let mut next = 0u64;
        for (i, l) in locs.iter().enumerate() {
            assert_eq!(l.basket_index, i as u32, "branch {b}: basket indexes consecutive");
            assert_eq!(l.first_entry, next, "branch {b}: entry spans contiguous");
            next += l.n_entries as u64;
            if i + 1 < locs.len() {
                assert!(
                    l.uncompressed_len as usize >= target,
                    "branch {b} basket {i}: {} logical bytes under the {target}-byte target",
                    l.uncompressed_len
                );
            }
        }
        assert_eq!(next, meta.n_entries, "branch {b}: spans cover the tree");
    }

    let events = synthetic::events(n_events, seed);
    assert_eq!(out.read_all_events().unwrap(), events);
    std::fs::remove_file(&src).ok();
    std::fs::remove_file(&dst).ok();
}

/// Small-basket branches feed one shared trained dictionary: the report
/// accounts for it, the output file carries the dictionary record, and
/// dictionary-seeded baskets round-trip exactly.
#[test]
fn repack_trains_a_shared_dictionary_for_small_basket_branches() {
    let src = common::tmp_path("repack", "dict_src");
    let dst = common::tmp_path("repack", "dict_dst");
    let n_events = 400usize;
    let seed = 0xd1c7;
    // 512-byte source baskets: every branch averages below the smallest
    // analyzer bucket, so every branch is dictionary-eligible.
    common::write_sample_tree(&src, Settings::new(Algorithm::Zstd, 5), n_events, 512, seed);

    let report = repack_file(&src, &dst, &RepackOptions::default()).unwrap();
    assert!(report.dictionary_bytes > 0, "small-basket corpus must train a dictionary");
    assert!(report.plans.iter().any(|p| p.dict_sampled));

    let mut out = TreeReader::open(&dst).unwrap();
    assert_eq!(out.dictionary().len(), report.dictionary_bytes);
    assert_eq!(out.read_all_events().unwrap(), synthetic::events(n_events, seed));

    // Disabling the budget must suppress the record entirely.
    let opts = RepackOptions { dict_budget: 0, ..RepackOptions::default() };
    let report = repack_file(&src, &dst, &opts).unwrap();
    assert_eq!(report.dictionary_bytes, 0);
    assert!(!report.plans.iter().any(|p| p.dict_sampled));
    let mut out = TreeReader::open(&dst).unwrap();
    assert!(out.dictionary().is_empty());
    assert_eq!(out.read_all_events().unwrap(), synthetic::events(n_events, seed));
    std::fs::remove_file(&src).ok();
    std::fs::remove_file(&dst).ok();
}

/// Strict repack of a damaged file must fail — and must not leave a
/// half-written output behind.
#[test]
fn repack_strict_fails_on_damage_and_leaves_no_output() {
    let src = common::tmp_path("repack", "strict_src");
    let dst = common::tmp_path("repack", "strict_dst");
    let n_events = 500usize;
    let meta =
        common::write_sample_tree(&src, Settings::new(Algorithm::Lz4, 9), n_events, 1024, 0xdead);
    let victim = meta
        .baskets
        .iter()
        .find(|l| l.branch_id == 2 && l.basket_index == 1)
        .expect("fixture has a second basket on branch 2");
    corrupt_basket(&src, victim.file_offset);

    assert!(repack_file(&src, &dst, &RepackOptions::default()).is_err());
    assert!(!dst.exists(), "failed repack must remove its partial output");
    std::fs::remove_file(&src).ok();
}

/// Salvage repack of the same damage keeps the intact complement: the
/// damaged span is dropped from *every* branch (the output stays
/// rectangular), reported exactly in the gaps, and the surviving rows
/// read back identical to the source complement.
#[test]
fn repack_salvage_drops_damaged_spans_and_reports_gaps() {
    let src = common::tmp_path("repack", "salvage_src");
    let dst = common::tmp_path("repack", "salvage_dst");
    let n_events = 500usize;
    let seed = 0xdead;
    let meta =
        common::write_sample_tree(&src, Settings::new(Algorithm::Lz4, 9), n_events, 1024, seed);
    let victim = *meta
        .baskets
        .iter()
        .find(|l| l.branch_id == 2 && l.basket_index == 1)
        .expect("fixture has a second basket on branch 2");
    corrupt_basket(&src, victim.file_offset);

    let opts = RepackOptions { salvage: true, ..RepackOptions::default() };
    let report = repack_file(&src, &dst, &opts).unwrap();
    assert!(!report.damage.is_empty(), "salvage must report the damaged basket");
    assert_eq!(report.gaps.len(), 1, "exactly the victim's span is lost: {:?}", report.gaps);
    let gap = &report.gaps[0];
    assert_eq!(gap.first_entry, victim.first_entry);
    assert_eq!(gap.n_entries, victim.n_entries as u64);
    assert_eq!(report.n_entries_in, n_events as u64);
    assert_eq!(report.n_entries_out, n_events as u64 - victim.n_entries as u64);

    // Every surviving row equals the source row, across all branches.
    let events = synthetic::events(n_events, seed);
    let expected: Vec<Vec<Value>> = events
        .iter()
        .enumerate()
        .filter(|(i, _)| {
            let e = *i as u64;
            e < gap.first_entry || e >= gap.end_entry()
        })
        .map(|(_, row)| row.clone())
        .collect();
    let mut out = TreeReader::open(&dst).unwrap();
    assert_eq!(out.read_all_events().unwrap(), expected);
    std::fs::remove_file(&src).ok();
    std::fs::remove_file(&dst).ok();
}

/// The decision surface end-to-end: a recorded profile pushes hot
/// branches onto the decode-speed lane (LZ4 family, window-sized
/// baskets) and cold branches onto the ratio lane (ZSTD-high / LZMA,
/// large baskets) — and applying the plan still rewrites exactly.
#[test]
fn profile_steers_branch_lanes_and_basket_targets() {
    let src = common::tmp_path("repack", "steer_src");
    let dst = common::tmp_path("repack", "steer_dst");
    let n_events = 2500usize;
    let seed = 0x7001;
    // 8 KiB source baskets so every wide branch clears the analyzer's
    // smallest feature bucket.
    let meta =
        common::write_sample_tree(&src, Settings::new(Algorithm::Zlib, 6), n_events, 8192, seed);

    let hot = "energy";
    let hot_id = meta.branches.iter().position(|d| d.name == hot).unwrap() as u32;
    let stored: u64 = meta
        .baskets
        .iter()
        .filter(|l| l.branch_id == hot_id)
        .map(|l| l.uncompressed_len as u64)
        .sum();
    // One scan that decoded the hot branch in full and nothing else.
    let mut profile = ReadFeedback::new();
    profile.record_scan(&[BranchReadStats {
        branch_id: hot_id,
        name: hot.into(),
        baskets: 3,
        entries: n_events as u64,
        logical_bytes: stored,
        compressed_bytes: stored / 2,
        ..BranchReadStats::default()
    }]);

    let opts = RepackOptions { profile: Some(profile), ..RepackOptions::default() };
    let plans = plan_branches(&src, &opts).unwrap();

    let hot_plan = plans.iter().find(|p| p.name == hot).unwrap();
    assert!((hot_plan.intensity.unwrap() - 1.0).abs() < 1e-9, "fully-read branch has intensity 1");
    assert_eq!(hot_plan.decision.use_case, UseCase::Analysis);
    assert_eq!(
        hot_plan.decision.settings.algorithm,
        Algorithm::Lz4,
        "hot branches ride the decode-speed lane, got {:?}",
        hot_plan.decision.settings
    );
    // The observed per-scan window (here: the whole branch) becomes the
    // re-chunk target.
    assert_eq!(hot_plan.decision.basket_bytes, stored as usize);

    let cold_plan = plans.iter().find(|p| p.name == "event_id").unwrap();
    assert_eq!(cold_plan.intensity, Some(0.0), "untouched branch has intensity 0");
    assert_eq!(cold_plan.decision.use_case, UseCase::Production);
    assert!(
        matches!(cold_plan.decision.settings.algorithm, Algorithm::Zstd | Algorithm::Lzma),
        "cold branches ride a ratio-bound lane, got {:?}",
        cold_plan.decision.settings
    );
    assert!(cold_plan.decision.basket_bytes >= 128 * 1024, "ratio lane keeps large baskets");

    // Applying the plan is still an exact rewrite.
    let report = repack_file(&src, &dst, &opts).unwrap();
    let applied = report.plans.iter().find(|p| p.name == hot).unwrap();
    assert_eq!(applied.decision.settings.algorithm, Algorithm::Lz4);
    let mut out = TreeReader::open(&dst).unwrap();
    assert_eq!(out.read_all_events().unwrap(), synthetic::events(n_events, seed));
    std::fs::remove_file(&src).ok();
    std::fs::remove_file(&dst).ok();
}

/// A profile with no recorded scans carries no signal; repack must say so
/// instead of silently planning from nothing.
#[test]
fn repack_rejects_an_empty_profile() {
    let src = common::tmp_path("repack", "empty_profile_src");
    common::write_sample_tree(&src, Settings::new(Algorithm::Lz4, 1), 50, 1024, 0x11);
    let opts = RepackOptions {
        profile: Some(ReadFeedback::new()),
        ..RepackOptions::default()
    };
    let err = plan_branches(&src, &opts).unwrap_err();
    assert!(err.to_string().contains("no scans"), "got: {err}");
    std::fs::remove_file(&src).ok();
}
