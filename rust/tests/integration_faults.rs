// This target is linted by the CI clippy job; it shares the library's
// style-lint policy (see the lint-policy note in rust/src/lib.rs).

#![allow(unknown_lints, clippy::style)]

//! Fault-tolerance properties (seeded, reproducible via `PROP_SEED`):
//!
//! 1. **Retry transparency** — under seeded transient faults (EIOs, short
//!    reads, latency spikes) the retried parallel reader must be
//!    **byte-identical** to a fault-free read, across the codec ×
//!    preconditioner grid and 1/2/4 workers, while the fault/retry
//!    counters prove the fault plan actually fired.
//! 2. **Salvage completeness** — corrupt `k` random baskets and the
//!    salvage scan must recover *exactly* the intact complement, with the
//!    damaged entry spans reported as gaps and one damage record per
//!    victim. Strict mode must keep rejecting, in parity with the serial
//!    oracle.
//! 3. **Decode-level damage** — a flipped stored LZ4 CRC is caught at
//!    decompression (not framing) and salvage degrades identically.
//! 4. **Backend independence** — properties 1 and 2 hold under every
//!    [`IoBackend`], and the coalesced backend's buffer slicing must
//!    keep attributing damage to exactly the overlapping basket.
//!
//! Fixtures come from the shared testkit (`mod common`): `PROP_SEED`
//! reproduces a failed run, `PROP_ROUNDS` caps the grid/round counts (see
//! rust/tests/common/mod.rs). `ROOTIO_FAULTS_BACKEND` pins the grids to
//! one I/O backend (CI re-runs the suite once per backend at elevated
//! rounds); unset, every backend runs at the default budget.

mod common;

use common::{grid, prop_rounds, sample, seeded, tmp_path, write_sample_tree};
use rootio::compression::{Algorithm, Settings};
use rootio::coordinator::{ParallelTreeReader, ReadAhead};
use rootio::gen::synthetic;
use rootio::rfile::{
    push_gap, BasketLoc, FaultSpec, GapSpan, IoBackend, IoConfig, RetryPolicy, TreeReader, Value,
};
use rootio::util::varint::get_uvarint;
use std::collections::BTreeSet;
use std::time::Duration;

/// I/O backend lanes for the property grids (see the module docs).
fn backends_under_test() -> Vec<IoBackend> {
    match std::env::var("ROOTIO_FAULTS_BACKEND") {
        Ok(name) => {
            let backend = IoBackend::parse(&name)
                .unwrap_or_else(|| panic!("ROOTIO_FAULTS_BACKEND={name}: unknown backend"));
            vec![backend]
        }
        Err(_) => IoBackend::all().to_vec(),
    }
}

/// Retries without sleeping: the backoff schedule is covered by the
/// source-layer unit tests; integration rounds only need the attempt loop.
fn instant_retry() -> RetryPolicy {
    RetryPolicy {
        max_attempts: 4,
        base_delay: Duration::ZERO,
        backoff: 1.0,
        max_delay: Duration::ZERO,
    }
}

/// Corrupt a basket *record* deterministically and codec-agnostically:
/// flip bits in the branch-id varint (first payload byte, `file_offset
/// + 4(len) + 1(kind)`), so the record still frames but fails the
/// identity check on decode.
fn corrupt_identity(path: &std::path::Path, loc: &BasketLoc) {
    let mut bytes = std::fs::read(path).unwrap();
    bytes[loc.file_offset as usize + 5] ^= 0x3F;
    std::fs::write(path, bytes).unwrap();
}

/// Expected salvage output for one branch: the event column minus the
/// victims' entry spans, plus the merged gap list.
fn intact_complement(
    events: &[Vec<Value>],
    branch_id: u32,
    victims: &[BasketLoc],
) -> (Vec<Value>, Vec<GapSpan>) {
    let mut vals = Vec::new();
    'entries: for (e, row) in events.iter().enumerate() {
        for v in victims {
            let (a, b) = v.entry_span();
            if (e as u64) >= a && (e as u64) < b {
                continue 'entries;
            }
        }
        vals.push(row[branch_id as usize].clone());
    }
    let mut gaps = Vec::new();
    for v in victims {
        push_gap(&mut gaps, GapSpan { first_entry: v.first_entry, n_entries: v.n_entries as u64 });
    }
    (vals, gaps)
}

#[test]
fn transient_faults_with_retry_are_byte_identical_to_fault_free() {
    let (mut rng, _guard) = seeded(0xFA17);
    let event_seed = rng.next_u64();
    let events = synthetic::events(100, event_seed);
    let settings_grid = sample(grid(), prop_rounds(12));
    let backends = backends_under_test();
    let (mut faults_total, mut retries_total) = (0u64, 0u64);
    for (i, settings) in settings_grid.into_iter().enumerate() {
        let basket_size = rng.range(256, 8192);
        let path = tmp_path("faults_retry", &format!("grid{i}"));
        write_sample_tree(&path, settings, events.len(), basket_size, event_seed);
        for workers in [1usize, 2, 4] {
            let spec = FaultSpec {
                seed: rng.next_u64(),
                transient: 0.35,
                short_read: 0.35,
                delay: 0.05,
                latency: Duration::from_micros(20),
                // bit_flip stays 0.0: flips are *undetectable* at this
                // layer by design, so they would (correctly) break byte
                // identity. max_consecutive=2 < max_attempts=4 keeps the
                // retry loop guaranteed to converge.
                ..FaultSpec::default()
            };
            // Faults inject *below* the backend, so each backend's
            // batching (group fills, image load, windowed ranges) must
            // absorb the same seeded plan and still converge.
            for &backend in &backends {
                let par = ParallelTreeReader::open(&path, ReadAhead { workers, depth: 4 })
                    .unwrap()
                    .with_faults(spec)
                    .with_retry(instant_retry())
                    .with_io(IoConfig::for_backend(backend));
                let got = par.read_all_events().unwrap();
                assert_eq!(
                    got,
                    events,
                    "{} x{workers}w io={backend} under faults",
                    settings.label()
                );
                faults_total += par.fault_stats().total();
                retries_total += par.read_retries();
                assert_eq!(
                    par.metrics_snapshot().read_retries,
                    par.read_retries(),
                    "metrics bridge out of sync (io={backend})"
                );
            }
        }
        std::fs::remove_file(&path).ok();
    }
    // Across the whole grid the seeded plan must actually have fired —
    // otherwise the identity assertions above proved nothing.
    assert!(faults_total > 0, "fault plan never fired");
    assert!(retries_total > 0, "retry layer never engaged");
}

#[test]
fn salvage_recovers_exact_intact_complement_and_strict_rejects() {
    let (mut rng, _guard) = seeded(0x5A17A6E);
    let lanes = [
        Settings::new(Algorithm::Zstd, 5),
        Settings::new(Algorithm::Lz4, 1),
        Settings::new(Algorithm::Zlib, 6),
    ];
    for round in 0..prop_rounds(6) {
        let settings = lanes[round % lanes.len()];
        let event_seed = rng.next_u64();
        let n_events = 150 + rng.range(0, 150);
        let basket_size = rng.range(512, 4096);
        let events = synthetic::events(n_events, event_seed);
        let path = tmp_path("faults_salvage", &format!("r{round}"));
        let meta = write_sample_tree(&path, settings, n_events, basket_size, event_seed);

        // Corrupt k distinct random baskets (identity-varint flip).
        // Rng::range is inclusive on both ends.
        let k = rng.range(1, 3);
        let mut victims: BTreeSet<usize> = BTreeSet::new();
        while victims.len() < k.min(meta.baskets.len()) {
            victims.insert(rng.range(0, meta.baskets.len() - 1));
        }
        let victims: Vec<BasketLoc> = victims.iter().map(|&i| meta.baskets[i]).collect();
        for v in &victims {
            corrupt_identity(&path, v);
        }
        let hit_branches: BTreeSet<u32> = victims.iter().map(|v| v.branch_id).collect();

        // Strict parity: the serial oracle rejects every branch that
        // owns a victim, and the strict pipeline must agree under every
        // I/O backend (rotating the worker count across rounds).
        let mut serial = TreeReader::open(&path).unwrap();
        for &b in &hit_branches {
            assert!(serial.read_branch(b).is_err(), "serial oracle accepted damaged branch {b}");
        }
        let workers = [1usize, 2, 4][round % 3];
        for backend in backends_under_test() {
            let par = serial
                .read_ahead(ReadAhead { workers, depth: 4 })
                .with_io(IoConfig::for_backend(backend));
            for &b in &hit_branches {
                assert!(
                    par.read_branch(b).is_err(),
                    "strict pipeline (io={backend}) accepted damaged branch {b}"
                );
            }

            // Salvage: every branch yields exactly the intact
            // complement, with the victims' entry spans as (merged) gaps
            // and one damage record per victim basket — regardless of
            // how the backend batched the bytes underneath.
            for b in 0..meta.branches.len() as u32 {
                let branch_victims: Vec<BasketLoc> =
                    victims.iter().filter(|v| v.branch_id == b).copied().collect();
                let col = par.read_branch_salvage(b).unwrap();
                let (want_vals, want_gaps) = intact_complement(&events, b, &branch_victims);
                assert_eq!(
                    col.values, want_vals,
                    "branch {b} salvage values (round {round}, io={backend})"
                );
                assert_eq!(
                    col.gaps, want_gaps,
                    "branch {b} salvage gaps (round {round}, io={backend})"
                );
                assert_eq!(
                    col.damage.len(),
                    branch_victims.len(),
                    "branch {b} damage records (round {round}, io={backend})"
                );
                let lost: u64 = branch_victims.iter().map(|v| v.n_entries as u64).sum();
                assert_eq!(col.entries_skipped(), lost);
                assert_eq!(col.values.len() as u64 + lost, meta.n_entries);
            }
        }
        std::fs::remove_file(&path).ok();
    }
}

/// Walk a basket record's payload (5 uvarints: branch id, basket index,
/// n_entries, data_len, n_offsets) to the engine blob offset.
fn blob_offset(bytes: &[u8], loc: &BasketLoc) -> usize {
    let mut pos = loc.file_offset as usize + 5;
    for _ in 0..5 {
        let (_, n) = get_uvarint(&bytes[pos..]).expect("basket payload varint");
        pos += n;
    }
    pos
}

#[test]
fn flipped_lz4_stored_crc_is_rejected_strictly_and_salvaged() {
    let (mut rng, _guard) = seeded(0xC2C);
    let event_seed = rng.next_u64();
    let n_events = 300;
    let events = synthetic::events(n_events, event_seed);
    let path = tmp_path("faults_salvage", "lz4crc");
    let meta =
        write_sample_tree(&path, Settings::new(Algorithm::Lz4, 9), n_events, 1024, event_seed);

    // Find a basket whose span actually carries the LZ4 tag — runs that
    // did not compress fall back to a raw span with no stored CRC.
    let mut bytes = std::fs::read(&path).unwrap();
    let victim = *meta
        .baskets
        .iter()
        .find(|loc| {
            let at = blob_offset(&bytes, loc);
            &bytes[at..at + 2] == b"L4"
        })
        .expect("no LZ4-compressed basket in a level-9 synthetic file");
    // Engine span: 10-byte header, then the LZ4 body's leading 4-byte
    // stored CRC32 — flip one CRC byte so framing stays valid and only
    // the payload integrity check can catch it.
    let at = blob_offset(&bytes, &victim) + 10;
    bytes[at] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();

    let mut serial = TreeReader::open(&path).unwrap();
    let par = serial.read_ahead(ReadAhead { workers: 2, depth: 4 });
    assert!(serial.read_branch(victim.branch_id).is_err(), "serial oracle accepted bad CRC");
    let strict_err = format!("{:#}", par.read_branch(victim.branch_id).unwrap_err());
    assert!(
        strict_err.contains(&format!("file offset {}", victim.file_offset)),
        "strict error lacks location context: {strict_err}"
    );

    let col = par.read_branch_salvage(victim.branch_id).unwrap();
    let (want_vals, want_gaps) = intact_complement(&events, victim.branch_id, &[victim]);
    assert_eq!(col.values, want_vals);
    assert_eq!(col.gaps, want_gaps);
    assert_eq!(col.damage.len(), 1);
    assert_eq!(col.damage[0].loc.basket_index, victim.basket_index);
    std::fs::remove_file(&path).ok();
}

#[test]
fn coalesced_slicing_preserves_per_basket_damage_attribution() {
    let (mut rng, _guard) = seeded(0xC0A7E5CE);
    let event_seed = rng.next_u64();
    let n_events = 240;
    let events = synthetic::events(n_events, event_seed);
    let path = tmp_path("faults_coalesce", "attrib");
    let meta =
        write_sample_tree(&path, Settings::new(Algorithm::Zstd, 3), n_events, 512, event_seed);
    let n_records = meta.baskets.len() as u64;
    assert!(n_records >= 8, "need a multi-record file to form merge groups");

    // Clean full sweep first: contiguous record spans must merge, so the
    // coalesced backend stays far under the 2-reads-per-record pread
    // floor — counter-asserted through the metrics snapshot, the same
    // surface the CLI report and the io_backends bench lanes read.
    let par = ParallelTreeReader::open(&path, ReadAhead { workers: 2, depth: 4 })
        .unwrap()
        .with_io(IoConfig::for_backend(IoBackend::Coalesced));
    assert_eq!(par.read_all_events().unwrap(), events);
    let snap = par.metrics_snapshot();
    assert!(
        snap.io_syscalls * 4 <= 2 * n_records,
        "coalescing barely batched: {} physical reads for {n_records} records",
        snap.io_syscalls
    );
    assert!(
        snap.io_requests_coalesced > 0 && snap.io_bytes_merged > 0,
        "merge counters never moved: coalesced={} merged={}",
        snap.io_requests_coalesced,
        snap.io_bytes_merged
    );

    // Flip one identity varint mid-file. The victim's bytes travel
    // inside a merge group shared with many intact records; slicing the
    // group buffer back into per-basket payloads must hand the damage to
    // exactly the overlapping basket and nothing else.
    let victim = meta.baskets[meta.baskets.len() / 2];
    corrupt_identity(&path, &victim);
    let par = ParallelTreeReader::open(&path, ReadAhead { workers: 2, depth: 4 })
        .unwrap()
        .with_io(IoConfig::for_backend(IoBackend::Coalesced));
    for b in 0..meta.branches.len() as u32 {
        let branch_victims: Vec<BasketLoc> =
            [victim].into_iter().filter(|v| v.branch_id == b).collect();
        let col = par.read_branch_salvage(b).unwrap();
        let (want_vals, want_gaps) = intact_complement(&events, b, &branch_victims);
        assert_eq!(col.values, want_vals, "branch {b}: intact complement must survive slicing");
        assert_eq!(col.gaps, want_gaps, "branch {b} gaps");
        assert_eq!(
            col.damage.len(),
            branch_victims.len(),
            "branch {b}: damage attributed to the wrong basket"
        );
        if let Some(d) = col.damage.first() {
            assert_eq!((d.loc.branch_id, d.loc.basket_index), (b, victim.basket_index));
        }
    }
    std::fs::remove_file(&path).ok();
}
