// This target is linted by the CI clippy job; it shares the library's
// style-lint policy (see the lint-policy note in rust/src/lib.rs).

#![allow(unknown_lints, clippy::style)]

//! Entropy-coder conformance suite (PR-8 satellite): every entropy lane —
//! dual-state FSE, quad-state FSE, and the Huff0-style multi-stream
//! Huffman literals coder — against its retained naive oracle, across the
//! shared testkit corpora, at every feasible table log, plus truncation /
//! bit-flip rejection parity and the degenerate-input table.
//!
//! Rejection parity uses the accept/reject discipline, not error-value
//! equality: on a corrupt stream fast and naive must both accept (with
//! identical output) or both reject; the error *values* may differ.
//!
//! The suite also owns the cross-version compatibility fixture: a
//! committed RFIL **v2** file (generated and independently re-parsed by
//! `python/tests/gen_compat_fixture.py`, never by this crate's writer)
//! must read event-for-event identical under today's v3 reader.

mod common;

use common::{corpus, prop_rounds, seeded, tmp_path};
use rootio::rfile::{TreeReader, Value};
use rootio::util::bitio::BitReader;
use rootio::util::rng::Rng;
use rootio::util::varint::Cursor;
use rootio::zstd::{fse, huff0};

/// Build enc/dec tables for `data` at `table_log`, or `None` when the log
/// cannot hold the alphabet (the suite probes infeasible logs on purpose).
fn tables_at(data: &[u8], table_log: u32) -> Option<(fse::EncTable, fse::DecTable)> {
    let hist = fse::histogram(data);
    let norm = fse::normalize_counts(&hist, data.len() as u64, table_log).ok()?;
    let enc = fse::EncTable::new(&norm, table_log).expect("enc table");
    let dec = fse::DecTable::new(&norm, table_log).expect("dec table");
    Some((enc, dec))
}

/// The table logs each payload is driven through: a deliberately small
/// one (infeasible for wide alphabets — exercises the clean-error path),
/// two mid logs, and the zstd literal maximum.
const TABLE_LOGS: [u32; 4] = [7, 9, 11, fse::MAX_TABLE_LOG];

/// Accept/reject parity check for a pair of decode outcomes.
fn assert_parity(
    fast: Result<Vec<u16>, fse::FseError>,
    naive: Result<Vec<u16>, fse::FseError>,
    what: &str,
) {
    match (fast, naive) {
        (Ok(a), Ok(b)) => assert_eq!(a, b, "{what}: both accepted, different symbols"),
        (Err(_), Err(_)) => {}
        (f, n) => panic!("{what}: fast {:?} vs naive {:?}", f.is_ok(), n.is_ok()),
    }
}

fn decode2(dec: &fse::DecTable, payload: &[u8], init: [u16; 2], n: usize) -> Result<Vec<u16>, fse::FseError> {
    let mut out = Vec::new();
    dec.decode_interleaved(&mut BitReader::new(payload), init, n, &mut out)?;
    Ok(out)
}

fn decode2_naive(dec: &fse::DecTable, payload: &[u8], init: [u16; 2], n: usize) -> Result<Vec<u16>, fse::FseError> {
    let mut out = Vec::new();
    fse::reference::decode_interleaved_naive(dec, &mut BitReader::new(payload), init, n, &mut out)?;
    Ok(out)
}

fn decode4(dec: &fse::DecTable, payload: &[u8], init: [u16; 4], n: usize) -> Result<Vec<u16>, fse::FseError> {
    let mut out = Vec::new();
    dec.decode_interleaved4(&mut BitReader::new(payload), init, n, &mut out)?;
    Ok(out)
}

fn decode4_naive(dec: &fse::DecTable, payload: &[u8], init: [u16; 4], n: usize) -> Result<Vec<u16>, fse::FseError> {
    let mut out = Vec::new();
    fse::reference::decode_interleaved4_naive(dec, &mut BitReader::new(payload), init, n, &mut out)?;
    Ok(out)
}

#[test]
fn fse_lanes_equal_naive_across_corpora_and_table_logs() {
    // Both interleaved widths, every corpus, every feasible table log:
    // encoders byte-identical (payload AND transmitted states) to the
    // naive oracle, decoders symbol-identical, and both widths round-trip
    // back to the input.
    let (mut rng, _guard) = seeded(0x4C0F_2026);
    let rounds = prop_rounds(6);
    for round in 0..rounds {
        for (ci, full) in corpus(&mut rng).into_iter().enumerate() {
            // Vary the slice per round so reduced-round CI still sees
            // fresh lengths (odd lengths exercise the lane tails).
            let n = rng.range(2, full.len());
            let data = &full[..n];
            let syms: Vec<u16> = data.iter().map(|&b| b as u16).collect();
            for log in TABLE_LOGS {
                let Some((enc, dec)) = tables_at(data, log) else { continue };
                // 2-state lane.
                let (p2, s2) = enc.encode_interleaved(data);
                let (p2n, s2n) = fse::reference::encode_interleaved_naive(&enc, &syms);
                assert_eq!(p2, p2n, "enc2 payload: round {round} corpus {ci} log {log}");
                assert_eq!(s2, s2n, "enc2 states: round {round} corpus {ci} log {log}");
                let d2 = decode2(&dec, &p2, s2, n).expect("decode2");
                assert_eq!(d2, decode2_naive(&dec, &p2, s2, n).expect("decode2 naive"));
                assert_eq!(d2, syms, "2-state roundtrip: round {round} corpus {ci} log {log}");
                // 4-state lane.
                let (p4, s4) = enc.encode_interleaved4(data);
                let (p4n, s4n) = fse::reference::encode_interleaved4_naive(&enc, &syms);
                assert_eq!(p4, p4n, "enc4 payload: round {round} corpus {ci} log {log}");
                assert_eq!(s4, s4n, "enc4 states: round {round} corpus {ci} log {log}");
                let d4 = decode4(&dec, &p4, s4, n).expect("decode4");
                assert_eq!(d4, decode4_naive(&dec, &p4, s4, n).expect("decode4 naive"));
                assert_eq!(d4, syms, "4-state roundtrip: round {round} corpus {ci} log {log}");
            }
        }
    }
}

#[test]
fn fse_rejection_parity_under_truncation_and_bit_flips() {
    // Corrupt streams: fast and naive decoders must agree on accept vs
    // reject for both widths. (Bit flips inside an FSE payload often still
    // decode — every bit pattern maps to a valid state — in which case
    // both must emit the same garbage symbols.)
    let (mut rng, _guard) = seeded(0x4C0F_BAD0);
    let rounds = prop_rounds(6);
    for round in 0..rounds {
        for full in corpus(&mut rng) {
            let n = rng.range(64, full.len());
            let data = &full[..n];
            let log = fse::optimal_table_log(n, fse::histogram(data).iter().filter(|&&c| c > 0).count(), 11);
            let Some((enc, dec)) = tables_at(data, log) else { continue };
            let (p2, s2) = enc.encode_interleaved(data);
            let (p4, s4) = enc.encode_interleaved4(data);
            // Truncations, including the empty payload.
            for cut in [0usize, p2.len() / 3, p2.len().saturating_sub(1)] {
                assert_parity(
                    decode2(&dec, &p2[..cut], s2, n),
                    decode2_naive(&dec, &p2[..cut], s2, n),
                    &format!("2-state cut {cut} round {round}"),
                );
            }
            for cut in [0usize, p4.len() / 3, p4.len().saturating_sub(1)] {
                assert_parity(
                    decode4(&dec, &p4[..cut], s4, n),
                    decode4_naive(&dec, &p4[..cut], s4, n),
                    &format!("4-state cut {cut} round {round}"),
                );
            }
            // Single-bit flips at random positions.
            for _ in 0..4 {
                if p2.is_empty() || p4.is_empty() {
                    break;
                }
                let mut bad2 = p2.clone();
                let at = rng.range(0, bad2.len() - 1);
                bad2[at] ^= 1 << rng.range(0, 7);
                assert_parity(
                    decode2(&dec, &bad2, s2, n),
                    decode2_naive(&dec, &bad2, s2, n),
                    &format!("2-state flip at {at} round {round}"),
                );
                let mut bad4 = p4.clone();
                let at = rng.range(0, bad4.len() - 1);
                bad4[at] ^= 1 << rng.range(0, 7);
                assert_parity(
                    decode4(&dec, &bad4, s4, n),
                    decode4_naive(&dec, &bad4, s4, n),
                    &format!("4-state flip at {at} round {round}"),
                );
            }
            // Invalid initial states must be rejected by both widths (the
            // naive decoders share the same entry guard).
            let size = 1u16 << enc.table_log();
            let bad_init2 = [s2[0], size.wrapping_sub(1)];
            assert!(decode2(&dec, &p2, bad_init2, n).is_err());
            assert!(decode2_naive(&dec, &p2, bad_init2, n).is_err());
            let bad_init4 = [s4[0], s4[1], s4[2], size.wrapping_sub(1)];
            assert!(decode4(&dec, &p4, bad_init4, n).is_err());
            assert!(decode4_naive(&dec, &p4, bad_init4, n).is_err());
        }
    }
}

#[test]
fn fse_degenerate_input_table() {
    // Empty input: normalization reports it, histograms agree.
    assert_eq!(fse::histogram(&[]), fse::reference::histogram_naive(&[]));
    assert!(fse::normalize_counts(&fse::histogram(&[]), 0, 9).is_err());

    // Single occurrence of a single symbol, and an all-one-byte block:
    // present == 1 gives the symbol the whole table; every lane width must
    // still round-trip (the planner would pick RLE, but the lane must be
    // legal — docs/FORMAT.md §7.3).
    for data in [vec![0x41u8], vec![0x41u8; 4096]] {
        let n = data.len();
        let syms: Vec<u16> = data.iter().map(|&b| b as u16).collect();
        let log = fse::optimal_table_log(n, 1, 11);
        let (enc, dec) = tables_at(&data, log).expect("degenerate tables");
        let (p2, s2) = enc.encode_interleaved(&data);
        assert_eq!((p2.clone(), s2), fse::reference::encode_interleaved_naive(&enc, &syms));
        assert_eq!(decode2(&dec, &p2, s2, n).unwrap(), syms);
        let (p4, s4) = enc.encode_interleaved4(&data);
        assert_eq!((p4.clone(), s4), fse::reference::encode_interleaved4_naive(&enc, &syms));
        assert_eq!(decode4(&dec, &p4, s4, n).unwrap(), syms);
    }

    // Tiny two-symbol inputs around the lane count: every length from 2
    // to 9 exercises each possible seeded/unseeded lane combination of
    // the 4-state encoder (lengths < 4 leave lanes unseeded).
    for n in 2usize..=9 {
        let data: Vec<u8> = (0..n).map(|i| if i % 2 == 0 { b'a' } else { b'z' }).collect();
        let syms: Vec<u16> = data.iter().map(|&b| b as u16).collect();
        let (enc, dec) = tables_at(&data, 5).expect("tiny tables");
        let (p4, s4) = enc.encode_interleaved4(&data);
        assert_eq!((p4.clone(), s4), fse::reference::encode_interleaved4_naive(&enc, &syms));
        assert_eq!(decode4(&dec, &p4, s4, n).unwrap(), syms, "n={n}");
    }

    // Max-size block (a full 128 KiB noise payload — the zstd literal
    // block ceiling): both widths survive and round-trip.
    let mut rng = Rng::new(0xB10C);
    let data = rng.bytes(128 << 10);
    let syms: Vec<u16> = data.iter().map(|&b| b as u16).collect();
    let (enc, dec) = tables_at(&data, fse::MAX_TABLE_LOG).expect("max block tables");
    let (p2, s2) = enc.encode_interleaved(&data[..]);
    assert_eq!(decode2(&dec, &p2, s2, data.len()).unwrap(), syms);
    let (p4, s4) = enc.encode_interleaved4(&data[..]);
    assert_eq!(decode4(&dec, &p4, s4, data.len()).unwrap(), syms);
}

#[test]
fn huff0_fast_equals_naive_across_corpora() {
    // Compressed blobs byte-identical (including the None fallback
    // decision), decoded bytes identical, round-trips exact.
    let (mut rng, _guard) = seeded(0x48FF_2026);
    let rounds = prop_rounds(6);
    for round in 0..rounds {
        for (ci, full) in corpus(&mut rng).into_iter().enumerate() {
            let n = rng.range(1, full.len());
            let data = &full[..n];
            let fast = huff0::compress(data);
            let naive = huff0::reference::compress_naive(data);
            assert_eq!(fast, naive, "blob: round {round} corpus {ci} n {n}");
            let Some(blob) = fast else { continue };
            let d = huff0::decompress(&blob, n).expect("huff0 decompress");
            let dn = huff0::reference::decompress_naive(&blob, n).expect("naive decompress");
            assert_eq!(d, dn, "round {round} corpus {ci} n {n}");
            assert_eq!(d, data, "roundtrip: round {round} corpus {ci} n {n}");
        }
    }
}

#[test]
fn huff0_rejection_parity_and_degenerates() {
    // Degenerate inputs: fewer than two distinct symbols is a fallback
    // (None) from both implementations.
    for data in [&b""[..], &b"A"[..], &[0x41u8; 10_000][..]] {
        assert_eq!(huff0::compress(data), None);
        assert_eq!(huff0::reference::compress_naive(data), None);
    }
    // Max-size block: 128 KiB of structured bytes still compresses and
    // round-trips through all four streams.
    let big: Vec<u8> = (0..128usize << 10).map(|i| (i % 7) as u8).collect();
    let blob = huff0::compress(&big).expect("big blob");
    assert_eq!(huff0::decompress(&blob, big.len()).unwrap(), big);

    // Corruption: truncations and bit flips, accept/reject parity.
    let (mut rng, _guard) = seeded(0x48FF_BAD0);
    let rounds = prop_rounds(6);
    for round in 0..rounds {
        for full in corpus(&mut rng) {
            let n = rng.range(16, full.len());
            let data = &full[..n];
            let Some(blob) = huff0::compress(data) else { continue };
            for cut in [0usize, 1, blob.len() / 2, blob.len().saturating_sub(1)] {
                let f = huff0::decompress(&blob[..cut], n);
                let nv = huff0::reference::decompress_naive(&blob[..cut], n);
                match (f, nv) {
                    (Ok(a), Ok(b)) => assert_eq!(a, b, "cut {cut} round {round}"),
                    (Err(_), Err(_)) => {}
                    (f, nv) => panic!("cut {cut}: fast {:?} vs naive {:?}", f.is_ok(), nv.is_ok()),
                }
            }
            for _ in 0..6 {
                let mut bad = blob.clone();
                let at = rng.range(0, bad.len() - 1);
                bad[at] ^= 1 << rng.range(0, 7);
                let f = huff0::decompress(&bad, n);
                let nv = huff0::reference::decompress_naive(&bad, n);
                match (f, nv) {
                    (Ok(a), Ok(b)) => assert_eq!(a, b, "flip at {at} round {round}"),
                    (Err(_), Err(_)) => {}
                    (f, nv) => panic!("flip at {at}: fast {:?} vs naive {:?}", f.is_ok(), nv.is_ok()),
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Cross-version compatibility: the committed v2 fixture.
// ---------------------------------------------------------------------------

/// The fixture's ground-truth events, mirroring `expected_events()` in
/// `python/tests/gen_compat_fixture.py` (which generated the file without
/// touching this crate's writer).
fn expected_fixture_events() -> Vec<Vec<Value>> {
    const TAG_NAMES: [&[u8]; 5] = [b"Muon_pt", b"Jet_eta", b"MET_phi", b"Tau_q", b"HLT_Iso"];
    (0..37)
        .map(|i| {
            let tag = if i % 7 == 3 {
                Vec::new()
            } else {
                let mut t = TAG_NAMES[i % 5].to_vec();
                t.push(b'0' + (i % 10) as u8);
                t
            };
            vec![Value::AU8(tag), Value::F32(i as f32 * 0.5 - 3.0)]
        })
        .collect()
}

#[test]
fn v2_fixture_reads_event_for_event_under_v3_reader() {
    let bytes: &[u8] = include_bytes!("fixtures/compat_v2.rfile");
    // It really is a v2 container — regenerating the fixture with a v3
    // stamp would silently gut this test.
    assert_eq!(&bytes[..4], b"RFIL");
    assert_eq!(&bytes[4..6], &[0u8, 2], "fixture must stay version 2");

    let path = tmp_path("conformance", "compat_v2.rfile");
    std::fs::write(&path, bytes).expect("staging fixture");
    let mut reader = TreeReader::open(&path).expect("v3 reader must accept a v2 file");
    assert_eq!(reader.meta.name, "Events");
    assert_eq!(reader.meta.n_entries, 37);
    assert_eq!(reader.meta.branches.len(), 2);
    let events = reader.read_all_events().expect("reading v2 fixture");
    assert_eq!(events, expected_fixture_events());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn v2_fixture_actually_exercises_the_dual_state_fse_lane() {
    // Parse the first basket record by hand and assert its RZS1 literal
    // section is MODE_FSE (2) — i.e. the compat test above really decodes
    // a dual-state FSE stream, not a raw/RLE section that any version
    // would accept.
    let bytes: &[u8] = include_bytes!("fixtures/compat_v2.rfile");
    // Record frame at offset 6: u32_be total_len + u8 kind.
    let total = u32::from_be_bytes(bytes[6..10].try_into().unwrap()) as usize;
    assert_eq!(bytes[10], 1, "first record must be a basket");
    let payload = &bytes[11..6 + total];
    let mut c = Cursor::new(payload);
    for field in ["branch_id", "basket_index", "n_entries", "data_len", "n_offsets"] {
        c.uvarint().unwrap_or_else(|| panic!("basket framing: {field}"));
    }
    let blob = &payload[c.pos()..];
    // 10-byte span header: tag, level, u24 comp, u24 uncomp, precond.
    assert_eq!(&blob[..2], b"ZS", "fixture span must be ZSTD, not raw fallback");
    assert_eq!(blob[2] & 0x0F, 5, "span level");
    let mut s = Cursor::new(&blob[10..]);
    s.uvarint().expect("rzs1 raw_len");
    assert_eq!(s.uvarint(), Some(0), "fixture block must be pure literals (n_seq = 0)");
    assert_eq!(s.u8(), Some(2), "literal section must be MODE_FSE (dual-state)");
}
