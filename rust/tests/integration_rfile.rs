// This target is linted by the CI clippy job; it shares the library's
// style-lint policy (see the lint-policy note in rust/src/lib.rs).

#![allow(unknown_lints, clippy::style)]

//! Integration: full write → read round-trips of the RFIL format across
//! codecs, preconditioners, basket sizes, and corruption scenarios.

use rootio::compression::{Algorithm, Settings};
use rootio::precond::Precond;
use rootio::rfile::{
    write_tree_serial, BranchDef, BranchType, TreeReader, Value, DEFAULT_BASKET_SIZE,
};
use rootio::util::rng::Rng;
use std::path::PathBuf;

fn tmp_path(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("rootio_test_{}_{}", std::process::id(), name));
    p
}

fn make_events(n: usize, seed: u64) -> Vec<Vec<Value>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let nmu = rng.poisson(2.5) as usize;
            vec![
                Value::I32(nmu as i32),
                Value::AF32((0..nmu).map(|_| rng.gauss(30.0, 15.0) as f32).collect()),
                Value::F64(rng.exponential(0.05)),
                Value::Bool(rng.chance(0.3)),
                Value::I64(i as i64 * 1000),
                Value::AU8(format!("run{}_{}", i / 100, i).into_bytes()),
            ]
        })
        .collect()
}

fn schema() -> Vec<BranchDef> {
    vec![
        BranchDef::new("nMuon", BranchType::I32),
        BranchDef::new("Muon_pt", BranchType::VarF32),
        BranchDef::new("MET_sumEt", BranchType::F64),
        BranchDef::new("HLT_IsoMu24", BranchType::Bool),
        BranchDef::new("event", BranchType::I64),
        BranchDef::new("tag", BranchType::VarU8),
    ]
}

fn roundtrip_with(settings: Settings, basket_size: usize, n: usize, name: &str) {
    let path = tmp_path(name);
    let events = make_events(n, 0xABCD);
    let meta = write_tree_serial(
        &path,
        "Events",
        schema(),
        settings,
        basket_size,
        events.iter().cloned(),
    )
    .expect("write");
    assert_eq!(meta.n_entries, n as u64);

    let mut reader = TreeReader::open(&path).expect("open");
    assert_eq!(reader.meta.n_entries, n as u64);
    assert_eq!(reader.meta.branches.len(), 6);
    let back = reader.read_all_events().expect("read");
    assert_eq!(back.len(), events.len());
    for (i, (a, b)) in events.iter().zip(&back).enumerate() {
        assert_eq!(a, b, "event {i} mismatch ({})", settings.label());
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn roundtrip_all_algorithms() {
    for (i, alg) in Algorithm::survey().iter().enumerate() {
        roundtrip_with(
            Settings::new(*alg, 5),
            DEFAULT_BASKET_SIZE,
            700,
            &format!("alg{i}"),
        );
    }
}

#[test]
fn roundtrip_uncompressed() {
    roundtrip_with(Settings::new(Algorithm::None, 0), DEFAULT_BASKET_SIZE, 300, "raw");
}

#[test]
fn roundtrip_with_preconditioners() {
    roundtrip_with(
        Settings::new(Algorithm::Lz4, 1).with_precond(Precond::BitShuffle(4)),
        DEFAULT_BASKET_SIZE,
        500,
        "bitshuf",
    );
    roundtrip_with(
        Settings::new(Algorithm::Zstd, 3).with_precond(Precond::Shuffle(4)),
        DEFAULT_BASKET_SIZE,
        500,
        "shuf",
    );
}

#[test]
fn roundtrip_tiny_baskets_many_flushes() {
    // Tiny basket size exercises multi-basket paths on every branch.
    roundtrip_with(Settings::new(Algorithm::Zlib, 1), 256, 400, "tiny");
}

#[test]
fn roundtrip_single_giant_basket() {
    roundtrip_with(Settings::new(Algorithm::Zstd, 2), 64 << 20, 1000, "giant");
}

#[test]
fn per_branch_settings_respected() {
    let path = tmp_path("perbranch");
    let mut branches = schema();
    branches[1] = BranchDef::new("Muon_pt", BranchType::VarF32)
        .with_settings(Settings::new(Algorithm::Lz4, 1).with_precond(Precond::BitShuffle(4)));
    branches[2] = BranchDef::new("MET_sumEt", BranchType::F64)
        .with_settings(Settings::new(Algorithm::Lzma, 6));
    let events = make_events(500, 77);
    write_tree_serial(
        &path,
        "Events",
        branches,
        Settings::new(Algorithm::Zstd, 4),
        4096,
        events.iter().cloned(),
    )
    .unwrap();
    let mut reader = TreeReader::open(&path).unwrap();
    let back = reader.read_all_events().unwrap();
    assert_eq!(back, events);
    // Per-branch settings survive the metadata round-trip.
    assert_eq!(
        reader.meta.branches[1].settings.unwrap().algorithm,
        Algorithm::Lz4
    );
    assert_eq!(
        reader.meta.branches[2].settings.unwrap().algorithm,
        Algorithm::Lzma
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn empty_tree() {
    let path = tmp_path("empty");
    write_tree_serial(
        &path,
        "Empty",
        schema(),
        Settings::default(),
        1024,
        std::iter::empty(),
    )
    .unwrap();
    let mut reader = TreeReader::open(&path).unwrap();
    assert_eq!(reader.meta.n_entries, 0);
    let back = reader.read_all_events().unwrap();
    assert!(back.is_empty());
    std::fs::remove_file(&path).ok();
}

#[test]
fn truncated_file_rejected() {
    let path = tmp_path("trunc");
    let events = make_events(200, 5);
    write_tree_serial(
        &path,
        "Events",
        schema(),
        Settings::default(),
        2048,
        events.into_iter(),
    )
    .unwrap();
    let bytes = std::fs::read(&path).unwrap();
    // Cut the file at several points; open must fail (no trailer) or the
    // basket reads must fail — never panic, never wrong data.
    for frac in [0.3, 0.7, 0.95] {
        let cut = (bytes.len() as f64 * frac) as usize;
        std::fs::write(&path, &bytes[..cut]).unwrap();
        match TreeReader::open(&path) {
            Err(_) => {}
            Ok(mut r) => {
                let _ = r.read_all_events().map(|evs| {
                    // If metadata happened to be intact, content must be too.
                    assert_eq!(evs.len() as u64, r.meta.n_entries);
                });
            }
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn corrupted_basket_detected() {
    let path = tmp_path("corrupt");
    let events = make_events(300, 9);
    write_tree_serial(
        &path,
        "Events",
        schema(),
        Settings::new(Algorithm::Zlib, 6), // zlib carries adler32
        2048,
        events.iter().cloned(),
    )
    .unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    // Flip a byte early in the record stream (inside some basket body).
    let target = bytes.len() / 3;
    bytes[target] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();
    match TreeReader::open(&path) {
        Err(_) => {}
        Ok(mut r) => match r.read_all_events() {
            Err(_) => {}
            Ok(back) => assert_ne!(back, events, "corruption silently ignored"),
        },
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn offset_arrays_match_paper_structure() {
    // Single-byte var entries => offsets 1,2,3,... (paper §2.2's example).
    let path = tmp_path("offsets");
    let branches = vec![BranchDef::new("c", BranchType::VarU8)];
    let events: Vec<Vec<Value>> = (0..100).map(|i| vec![Value::AU8(vec![i as u8])]).collect();
    write_tree_serial(
        &path,
        "T",
        branches,
        Settings::new(Algorithm::None, 0),
        1 << 20,
        events.into_iter(),
    )
    .unwrap();
    let mut reader = TreeReader::open(&path).unwrap();
    let locs = reader.baskets_for(0);
    assert_eq!(locs.len(), 1);
    let content = reader.read_basket(&locs[0]).unwrap();
    let expect: Vec<u32> = (1..=100).collect();
    assert_eq!(content.offsets, expect);
    std::fs::remove_file(&path).ok();
}
