//! Shared oracle testkit for the integration suites.
//!
//! Every property-style suite used to carry its own copy of the fixture
//! builders (codec × preconditioner grid, seeded corpus, temp-file
//! naming); this module is the single source of truth they all import via
//! `mod common;`. It also owns the reproducibility contract:
//!
//! * **`PROP_SEED`** (env, `0x…` hex or decimal) overrides a test's
//!   default RNG seed. Construct the RNG through [`seeded`]; the returned
//!   guard prints `seed=0x…` into the output of any panicking test, so a
//!   CI failure is reproducible locally with exactly one env var:
//!   `PROP_SEED=0x… cargo test -q --test <suite>`.
//! * **`PROP_ROUNDS`** (env) caps property-test rounds and grid cells
//!   through [`prop_rounds`] / [`sample`] (values above a test's default
//!   are clamped to the default, so it can only reduce work). The CI MSRV
//!   matrix leg sets it so the pinned-toolchain build stops being the
//!   long pole; stable runs the full grid. Documented in
//!   docs/BENCHMARKS.md §"CI knobs".

// Each test target compiles this module separately and uses a different
// subset of it; unused helpers in one target are not dead code.
#![allow(dead_code)]

use rootio::compression::{Algorithm, Settings};
use rootio::gen::synthetic;
use rootio::precond::Precond;
use rootio::rfile::{write_tree_serial, TreeMeta};
use rootio::util::rng::Rng;
use std::path::PathBuf;

/// Per-process temp file path, namespaced by suite and fixture name.
pub fn tmp_path(suite: &str, name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("rootio_{suite}_{}_{name}", std::process::id()));
    p
}

/// The full codec × preconditioner grid the container supports — the
/// canonical coverage matrix for reader/writer oracle equivalence tests.
pub fn grid() -> Vec<Settings> {
    let mut v = Vec::new();
    for (alg, level) in [
        (Algorithm::None, 0u8),
        (Algorithm::Zlib, 6),
        (Algorithm::CfZlib, 1),
        (Algorithm::Lz4, 1),
        (Algorithm::Lz4, 9),
        (Algorithm::Zstd, 5),
        (Algorithm::Lzma, 6),
        (Algorithm::OldRoot, 6),
    ] {
        for precond in [
            Precond::None,
            Precond::BitShuffle(4),
            Precond::Shuffle(4),
            Precond::Delta(4),
        ] {
            v.push(Settings::new(alg, level).with_precond(precond));
        }
    }
    v
}

/// The survey settings the corruption suite attacks: every algorithm at a
/// mid level, plus the two preconditioned lanes that change span framing.
pub fn survey_settings() -> Vec<Settings> {
    let mut v: Vec<Settings> = Algorithm::survey()
        .iter()
        .map(|&a| Settings::new(a, 6))
        .collect();
    v.push(Settings::new(Algorithm::Lz4, 1).with_precond(Precond::BitShuffle(4)));
    v.push(Settings::new(Algorithm::Zstd, 5).with_precond(Precond::Shuffle(4)));
    v
}

/// Deterministic byte corpora for codec-level fault injection: structured
/// offsets, pure noise, and repetitive text-ish payloads.
pub fn corpus(rng: &mut Rng) -> Vec<Vec<u8>> {
    vec![
        (1u32..=20_000).flat_map(|i| i.to_be_bytes()).collect(),
        rng.bytes(30_000),
        {
            let mut v = Vec::new();
            while v.len() < 40_000 {
                v.extend_from_slice(b"basket payload with structure ");
                let extra = rng.bytes(3);
                v.extend_from_slice(&extra);
            }
            v
        },
    ]
}

/// Write a synthetic-workload tree file: the standard on-disk fixture of
/// the reader/projection suites. Deterministic for a given `seed`.
pub fn write_sample_tree(
    path: &std::path::Path,
    settings: Settings,
    n_events: usize,
    basket_size: usize,
    seed: u64,
) -> TreeMeta {
    let events = synthetic::events(n_events, seed);
    write_tree_serial(
        path,
        "Events",
        synthetic::schema(),
        settings,
        basket_size,
        events.iter().cloned(),
    )
    .expect("writing sample tree")
}

/// Effective round count for a property test: `PROP_ROUNDS` (clamped to
/// `[1, default]`) or the test's own default. See the module docs.
pub fn prop_rounds(default: usize) -> usize {
    match std::env::var("PROP_ROUNDS") {
        Ok(v) if !v.trim().is_empty() => match v.trim().parse::<usize>() {
            Ok(n) => n.clamp(1, default),
            Err(_) => panic!("PROP_ROUNDS='{v}' is not a round count"),
        },
        _ => default,
    }
}

/// Deterministically subsample `items` to at most `max` entries, spread
/// evenly across the list (so a reduced `PROP_ROUNDS` run still touches
/// every region of the grid, not just its head).
pub fn sample<T>(mut items: Vec<T>, max: usize) -> Vec<T> {
    let len = items.len();
    if max == 0 || len <= max {
        return items;
    }
    let mut keep = vec![false; len];
    for i in 0..max {
        keep[i * len / max] = true;
    }
    let mut j = 0;
    items.retain(|_| {
        let k = keep[j];
        j += 1;
        k
    });
    items
}

/// The seed a test should run with: `PROP_SEED` (hex `0x…` or decimal) or
/// the test's default.
pub fn prop_seed(default: u64) -> u64 {
    match std::env::var("PROP_SEED") {
        Ok(v) if !v.trim().is_empty() => {
            let v = v.trim();
            let parsed = match v.strip_prefix("0x").or_else(|| v.strip_prefix("0X")) {
                Some(hex) => u64::from_str_radix(hex, 16),
                None => v.parse(),
            };
            parsed.unwrap_or_else(|_| panic!("PROP_SEED='{v}' is not a u64 (0x… hex or decimal)"))
        }
        _ => default,
    }
}

/// Prints the run's seed when (and only when) the test panics, making
/// every property-test failure message carry its reproduction recipe.
/// Keep it alive for the whole test: `let (mut rng, _guard) = seeded(…);`
pub struct SeedGuard {
    seed: u64,
}

impl Drop for SeedGuard {
    fn drop(&mut self) {
        if std::thread::panicking() {
            eprintln!(
                "testkit: property test failed with seed=0x{:x} — rerun with \
                 PROP_SEED=0x{:x} cargo test",
                self.seed, self.seed
            );
        }
    }
}

/// Seeded RNG + panic-time seed reporter: the required entry point for
/// randomized tests (honors `PROP_SEED`, see module docs).
pub fn seeded(default_seed: u64) -> (Rng, SeedGuard) {
    let seed = prop_seed(default_seed);
    (Rng::new(seed), SeedGuard { seed })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_spreads_across_the_list() {
        let v: Vec<usize> = (0..32).collect();
        assert_eq!(sample(v.clone(), 40), v, "max above len keeps everything");
        let s = sample(v.clone(), 6);
        assert_eq!(s.len(), 6);
        assert_eq!(s[0], 0, "always includes the head");
        assert!(s.last().unwrap() >= &26, "reaches the tail region: {s:?}");
        assert!(s.windows(2).all(|w| w[0] < w[1]), "order preserved");
        assert_eq!(sample(v, 1), vec![0]);
        assert_eq!(sample(Vec::<usize>::new(), 3), Vec::<usize>::new());
    }

    #[test]
    fn grid_covers_every_algorithm_and_precond() {
        let g = grid();
        assert_eq!(g.len(), 32);
        for alg in [
            Algorithm::None,
            Algorithm::Zlib,
            Algorithm::CfZlib,
            Algorithm::Lz4,
            Algorithm::Zstd,
            Algorithm::Lzma,
            Algorithm::OldRoot,
        ] {
            assert!(g.iter().any(|s| s.algorithm == alg), "{alg:?} missing");
        }
        for p in [Precond::None, Precond::BitShuffle(4), Precond::Shuffle(4), Precond::Delta(4)] {
            assert!(g.iter().any(|s| s.precond == p), "{p:?} missing");
        }
    }
}
