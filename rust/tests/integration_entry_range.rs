// This target is linted by the CI clippy job; it shares the library's
// style-lint policy (see the lint-policy note in rust/src/lib.rs).

#![allow(unknown_lints, clippy::style)]

//! Entry-range read invariants (property-style, seeded): a sliced
//! projection through
//! [`rootio::coordinator::ParallelTreeReader::project_range`] must be
//! **byte-identical** to the full `read_columns` followed by an in-memory
//! slice — for any worker count (1/2/4), codec × preconditioner, and
//! either prefetch order — and the single-branch range reads
//! ([`rootio::rfile::TreeReader::read_range`],
//! [`rootio::coordinator::ParallelTreeReader::read_range`]) must agree
//! with the same oracle. Covered edge windows: empty ranges, ranges past
//! EOF, single entries, and ranges landing exactly on basket boundaries
//! (no head/tail trim on either side).
//!
//! Fixtures come from the shared testkit (`mod common`): `PROP_SEED`
//! reproduces a failed run, `PROP_ROUNDS` caps the grid (see
//! rust/tests/common/mod.rs).

mod common;

use common::{grid, prop_rounds, sample, seeded, tmp_path, write_sample_tree};
use rootio::compression::{Algorithm, Settings};
use rootio::coordinator::{ParallelTreeReader, PrefetchOrder, ProjectionPlan, ReadAhead};
use rootio::gen::synthetic;
use rootio::precond::Precond;
use rootio::rfile::{TreeReader, Value};

/// Slice-after-full-read oracle: `columns[slot][a..b]`, clamped like the
/// readers clamp.
fn slice_oracle(columns: &[Vec<Value>], a: u64, b: u64) -> Vec<Vec<Value>> {
    let n = columns.first().map(|c| c.len() as u64).unwrap_or(0);
    let (ca, cb) = (a.min(n) as usize, b.min(n).max(a.min(n)) as usize);
    columns.iter().map(|c| c[ca..cb].to_vec()).collect()
}

#[test]
fn sliced_projection_equals_full_read_then_slice_across_grid() {
    let (mut rng, _guard) = seeded(0x3A11CE);
    let events_seed = rng.next_u64();
    let n_events = 160u64;
    let n_branches = synthetic::schema().len() as u32;
    let settings_grid = sample(grid(), prop_rounds(usize::MAX));
    for (i, settings) in settings_grid.into_iter().enumerate() {
        // Small, varied baskets put many boundaries inside every window.
        let basket_size = rng.range(256, 4096);
        let path = tmp_path("erange", &format!("grid{i}"));
        write_sample_tree(&path, settings, n_events as usize, basket_size, events_seed);

        // Rotate the projected subset per setting: k in 1..=3.
        let k = 1 + (i % 3);
        let ids: Vec<u32> = (0..k).map(|j| ((i + 5 * j) as u32) % n_branches).collect();

        // Full-read oracle via the serial reader.
        let mut serial = TreeReader::open(&path).unwrap();
        let full: Vec<Vec<Value>> =
            ids.iter().map(|&id| serial.read_branch(id).unwrap()).collect();

        // Window mix: two random windows plus rotating edge cases —
        // empty, past-EOF, tail-crossing, single-entry, and one landing
        // exactly on a basket boundary of the first projected branch.
        let mut windows: Vec<(u64, u64)> = Vec::new();
        for _ in 0..2 {
            let a = rng.range(0, n_events as usize) as u64;
            let b = rng.range(a as usize, n_events as usize) as u64;
            windows.push((a, b));
        }
        let boundary_locs = serial.baskets_for(ids[0]);
        if boundary_locs.len() >= 2 {
            let first = boundary_locs[rng.range(1, boundary_locs.len() - 1)].first_entry;
            let last = boundary_locs
                .iter()
                .map(|l| l.first_entry)
                .find(|&e| e > first)
                .unwrap_or(n_events);
            windows.push((first, last)); // exact basket-boundary window
        }
        windows.push(match i % 4 {
            0 => (7.min(n_events), 7.min(n_events)),       // empty
            1 => (n_events + 3, n_events + 50),            // past EOF
            2 => (n_events - 5, n_events + 5),             // crosses EOF
            _ => (n_events / 2, n_events / 2 + 1),         // single entry
        });

        let order =
            if i % 2 == 0 { PrefetchOrder::FileOffset } else { PrefetchOrder::Submission };
        for &(a, b) in &windows {
            let oracle = slice_oracle(&full, a, b);
            for workers in [1usize, 2, 4] {
                let depth = rng.range(1, 8);
                let par = ParallelTreeReader::open(&path, ReadAhead { workers, depth }).unwrap();
                let plan =
                    ProjectionPlan::new(&par.meta, &ids, order).unwrap().slice(a, b);
                if order == PrefetchOrder::FileOffset {
                    assert!(
                        plan.is_monotonic_sweep(),
                        "{} sliced offset plan must stay one forward sweep",
                        settings.label()
                    );
                }
                let mut proj = par.project_plan(&plan).unwrap();
                let columns = proj.read_columns().unwrap();
                assert_eq!(
                    columns,
                    oracle,
                    "{} w={workers} d={depth} ids={ids:?} window=[{a},{b}) {order:?}",
                    settings.label()
                );
                // Stats only cover the sliced plan's baskets.
                let decoded: u64 = proj.branch_stats().iter().map(|s| s.baskets).sum();
                assert_eq!(decoded, plan.locs().len() as u64, "window=[{a},{b})");
            }
            // Single-branch range APIs against the same oracle.
            let par = ParallelTreeReader::open(&path, ReadAhead::with_workers(2)).unwrap();
            assert_eq!(
                par.read_range(ids[0], a..b).unwrap(),
                oracle[0],
                "{} parallel read_range window=[{a},{b})",
                settings.label()
            );
            assert_eq!(
                serial.read_range(ids[0], a..b).unwrap(),
                oracle[0],
                "{} serial read_range window=[{a},{b})",
                settings.label()
            );
        }
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn boundary_windows_decode_only_their_baskets() {
    // A window landing exactly on basket boundaries must decode exactly
    // the covered baskets (no neighbour is read) and need no trim; a
    // mid-basket window decodes its boundary baskets once each.
    let path = tmp_path("erange", "boundary");
    write_sample_tree(
        &path,
        Settings::new(Algorithm::Lz4, 1).with_precond(Precond::BitShuffle(4)),
        300,
        512,
        0xB0D1,
    );
    let mut serial = TreeReader::open(&path).unwrap();
    let id = serial.branch_id("px").unwrap();
    let locs = serial.baskets_for(id);
    assert!(locs.len() >= 4, "need several baskets, got {}", locs.len());
    let full = serial.read_branch(id).unwrap();

    let (a, b) = (locs[1].first_entry, locs[3].first_entry);
    let par = ParallelTreeReader::open(&path, ReadAhead::with_workers(2)).unwrap();
    let mut proj = par.project_range(&["px"], a..b).unwrap();
    let cols = proj.read_columns().unwrap();
    assert_eq!(cols[0].as_slice(), &full[a as usize..b as usize]);
    // Exactly baskets 1 and 2 were decoded: boundary alignment means the
    // neighbours never enter the plan.
    assert_eq!(proj.branch_stats()[0].baskets, 2);
    assert_eq!(
        proj.branch_stats()[0].entries,
        (locs[1].n_entries + locs[2].n_entries) as u64
    );

    // Mid-basket window: both boundary baskets decode whole, rows trim.
    let (a, b) = (locs[1].first_entry + 3, locs[2].first_entry + 2);
    let mut proj = par.project_range(&["px"], a..b).unwrap();
    let cols = proj.read_columns().unwrap();
    assert_eq!(cols[0].as_slice(), &full[a as usize..b as usize]);
    assert_eq!(proj.branch_stats()[0].baskets, 2);
    std::fs::remove_file(&path).ok();
}

#[test]
fn ranged_row_batches_match_the_slice() {
    let path = tmp_path("erange", "batches");
    write_sample_tree(&path, Settings::new(Algorithm::Zstd, 5), 280, 768, 0xBA7C);
    let mut serial = TreeReader::open(&path).unwrap();
    let names = ["event_id", "Track_pt", "is_good"];
    let cols: Vec<Vec<Value>> = names
        .iter()
        .map(|n| serial.read_branch(serial.branch_id(n).unwrap()).unwrap())
        .collect();
    let (a, b) = (33u64, 251u64);
    let par = ParallelTreeReader::open(&path, ReadAhead::with_workers(3)).unwrap();
    let mut proj = par.project_range(&names, a..b).unwrap();
    proj.set_max_batch_rows(29);
    let mut entry = a;
    while let Some(batch) = proj.next_batch() {
        let batch = batch.unwrap();
        assert_eq!(batch.first_entry, entry, "absolute entry ids");
        assert!(batch.len() <= 29 && !batch.is_empty());
        for (j, row) in batch.rows.iter().enumerate() {
            let e = (entry + j as u64) as usize;
            assert_eq!(row.len(), names.len());
            for (slot, v) in row.iter().enumerate() {
                assert_eq!(*v, cols[slot][e], "entry {e} slot {slot}");
            }
        }
        entry += batch.len() as u64;
    }
    assert_eq!(entry, b);
    assert_eq!(proj.entries_emitted(), b - a);
    assert!(proj.next_batch().is_none(), "drained range ends the stream");
    std::fs::remove_file(&path).ok();
}

#[test]
fn degenerate_windows_yield_no_rows_and_no_io() {
    let path = tmp_path("erange", "degenerate");
    write_sample_tree(&path, Settings::new(Algorithm::Lz4, 1), 120, 1024, 0xE0F);
    let mut serial = TreeReader::open(&path).unwrap();
    let par = ParallelTreeReader::open(&path, ReadAhead::with_workers(2)).unwrap();
    let n = par.meta.n_entries;
    for (a, b) in [(0, 0), (60, 60), (n, n), (n, n + 10), (n + 100, n + 200)] {
        let mut proj = par.project_range(&["px", "label"], a..b).unwrap();
        let cols = proj.read_columns().unwrap();
        assert!(cols.iter().all(|c| c.is_empty()), "window [{a},{b})");
        assert!(proj.branch_stats().iter().all(|s| s.baskets == 0), "no basket decoded");
        assert!(proj.next_batch().is_none());
        assert_eq!(par.read_range(0, a..b).unwrap(), Vec::<Value>::new());
        assert_eq!(serial.read_range(0, a..b).unwrap(), Vec::<Value>::new());
    }
    // Unknown branch id errors on the range path like the full path.
    assert!(par.read_range(999, 0..10).is_err());
    assert!(serial.read_range(999, 0..10).is_err());
    std::fs::remove_file(&path).ok();
}
