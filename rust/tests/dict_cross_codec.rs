// This target is linted by the CI clippy job; it shares the library's
// style-lint policy (see the lint-policy note in rust/src/lib.rs).

#![allow(unknown_lints, clippy::style)]

//! Paper §3: "while ZSTD can be used to generate the dictionary, the
//! generated dictionaries are useable for ZLIB and LZ4 as well."
//!
//! This suite proves exactly that claim end-to-end: one dictionary trained
//! by `zstd::dict::train` improves compression of small held-out baskets
//! under the ZSTD-style codec, zlib (RFC 1950 FDICT), and LZ4 (prefix
//! dictionary) — and every dict stream round-trips (and fails loudly with
//! the wrong dictionary where the format can tell).

use rootio::compression::{Algorithm, Engine, Settings};
use rootio::deflate::zlib::{zlib_compress_dict, zlib_decompress_dict};
use rootio::deflate::Flavor;
use rootio::lz4::{lz4_decompress_dict, Lz4Encoder, Lz4Method};
use rootio::util::rng::Rng;
use rootio::zstd::dict::{synthetic_corpus, train_from_corpus};

const MAX: usize = 64 << 20;

fn setup() -> (Vec<u8>, Vec<Vec<u8>>) {
    let corpus = synthetic_corpus(300, 320, 0xD1C7_2026);
    let (train, test) = corpus.split_at(220);
    let dict = train_from_corpus(&train.to_vec(), 8192);
    assert!(!dict.is_empty());
    (dict, test.to_vec())
}

#[test]
fn one_dictionary_helps_all_three_codecs() {
    let (dict, test) = setup();
    let mut totals = [(0usize, 0usize); 3]; // (plain, dict) per codec
    let mut lz4 = Lz4Encoder::new();
    for sample in &test {
        // ZSTD-style.
        let p = rootio::zstd::zstd_compress_dict(sample, &[], 6);
        let d = rootio::zstd::zstd_compress_dict(sample, &dict, 6);
        assert_eq!(
            rootio::zstd::zstd_decompress_dict(&d, &dict, MAX).unwrap(),
            *sample
        );
        totals[0].0 += p.len();
        totals[0].1 += d.len();
        // zlib FDICT.
        let p = rootio::deflate::zlib_compress(sample, Flavor::Cloudflare, 6);
        let d = zlib_compress_dict(sample, &dict, Flavor::Cloudflare, 6);
        assert_eq!(
            zlib_decompress_dict(&d, &dict, sample.len(), MAX).unwrap(),
            *sample
        );
        totals[1].0 += p.len();
        totals[1].1 += d.len();
        // LZ4 prefix dict.
        let p = lz4.compress(sample, Lz4Method::Fast { accel: 1 });
        let d = lz4.compress_dict(sample, &dict, Lz4Method::Fast { accel: 1 });
        assert_eq!(lz4_decompress_dict(&d, &dict, sample.len()).unwrap(), *sample);
        totals[2].0 += p.len();
        totals[2].1 += d.len();
    }
    for (name, (plain, with_dict)) in ["zstd", "zlib", "lz4"].iter().zip(totals) {
        assert!(
            (with_dict as f64) < 0.92 * plain as f64,
            "{name}: dict {with_dict} vs plain {plain} — dictionary did not help"
        );
    }
}

#[test]
fn zlib_fdict_wrong_dictionary_rejected() {
    let (dict, test) = setup();
    let sample = &test[0];
    let c = zlib_compress_dict(sample, &dict, Flavor::Reference, 6);
    // FDICT streams carry DICTID = adler32(dict): a wrong dict must be
    // rejected by id before any decoding happens.
    let mut rng = Rng::new(5);
    let wrong = rng.bytes(dict.len());
    let err = zlib_decompress_dict(&c, &wrong, sample.len(), MAX).unwrap_err();
    assert_eq!(err.0, "dictionary id mismatch");
    // And no dictionary at all is also rejected.
    assert!(zlib_decompress_dict(&c, &[], sample.len(), MAX).is_err());
}

#[test]
fn lz4_wrong_dictionary_caught_by_content_checksum() {
    let (dict, test) = setup();
    let sample = &test[1];
    let mut lz4 = Lz4Encoder::new();
    let c = lz4.compress_dict(sample, &dict, Lz4Method::Fast { accel: 1 });
    let mut rng = Rng::new(6);
    let wrong = rng.bytes(dict.len());
    match lz4_decompress_dict(&c, &wrong, sample.len()) {
        Err(_) => {}
        Ok(d) => assert_ne!(&d, sample, "wrong dict silently produced the original"),
    }
}

#[test]
fn engine_routes_dictionary_to_all_codecs() {
    let (dict, test) = setup();
    let mut engine = Engine::new();
    engine.set_dictionary(dict.clone());
    for alg in [Algorithm::Zstd, Algorithm::Zlib, Algorithm::CfZlib, Algorithm::Lz4] {
        let s = Settings::new(alg, 6);
        let mut plain_engine = Engine::new();
        let mut total_plain = 0usize;
        let mut total_dict = 0usize;
        for sample in &test {
            let c = engine.compress(sample, &s);
            assert_eq!(&engine.decompress(&c).unwrap(), sample, "{}", s.label());
            total_dict += c.len();
            total_plain += plain_engine.compress(sample, &s).len();
        }
        assert!(
            total_dict < total_plain,
            "{}: dict {total_dict} vs plain {total_plain}",
            s.label()
        );
    }
}

#[test]
fn fdict_streams_are_valid_rfc1950() {
    // Header checks: FDICT bit set, FCHECK valid, DICTID == adler32(dict).
    let (dict, test) = setup();
    let c = zlib_compress_dict(&test[0], &dict, Flavor::Reference, 6);
    assert_eq!(c[0] & 0x0F, 8, "CM=deflate");
    assert_ne!(c[1] & 0x20, 0, "FDICT set");
    assert_eq!(((c[0] as u16) << 8 | c[1] as u16) % 31, 0, "FCHECK");
    let dictid = u32::from_be_bytes(c[2..6].try_into().unwrap());
    assert_eq!(dictid, rootio::checksum::adler32(&dict));
}
