// This target is linted by the CI clippy job; it shares the library's
// style-lint policy (see the lint-policy note in rust/src/lib.rs).

#![allow(unknown_lints, clippy::style)]

//! Pipeline invariants (property-style, seeded): for any worker count,
//! queue depth, basket size, and workload, the parallel writer must produce
//! a file whose *content* round-trips identically to the serial writer's —
//! no basket lost, duplicated, or reordered within a branch.
//!
//! Fixtures come from the shared testkit (`mod common`): `PROP_SEED`
//! reproduces a failed run, `PROP_ROUNDS` caps the round count (see
//! rust/tests/common/mod.rs).

mod common;

use common::{prop_rounds, seeded, tmp_path};
use rootio::compression::{Algorithm, Settings};
use rootio::coordinator::{write_tree_parallel, PipelineConfig};
use rootio::gen::synthetic;
use rootio::precond::Precond;
use rootio::rfile::{write_tree_serial, TreeReader, Value};

#[test]
fn parallel_content_equals_serial_content() {
    let (mut rng, _guard) = seeded(0x9199);
    for round in 0..prop_rounds(6) {
        let n_events = rng.range(50, 600);
        let events = synthetic::events(n_events, round as u64 + 1);
        let basket_size = [512usize, 4096, 65536][round % 3];
        let workers = rng.range(1, 8);
        let queue_depth = rng.range(1, 16);
        let settings = Settings::new(
            [Algorithm::Zlib, Algorithm::Lz4, Algorithm::Zstd][round % 3],
            (round % 9 + 1) as u8,
        );

        let ser_path = tmp_path("pipe", &format!("ser{round}"));
        let par_path = tmp_path("pipe", &format!("par{round}"));
        write_tree_serial(
            &ser_path,
            "Events",
            synthetic::schema(),
            settings,
            basket_size,
            events.iter().cloned(),
        )
        .unwrap();
        let (meta, snap) = write_tree_parallel(
            &par_path,
            "Events",
            synthetic::schema(),
            settings,
            basket_size,
            PipelineConfig { workers, queue_depth, dictionary: Vec::new() },
            events.iter().cloned(),
        )
        .unwrap();
        assert_eq!(meta.n_entries, n_events as u64);
        assert_eq!(snap.baskets as usize, meta.baskets.len());

        let mut ser = TreeReader::open(&ser_path).unwrap();
        let mut par = TreeReader::open(&par_path).unwrap();
        // Same basket directory structure per branch.
        assert_eq!(ser.meta.baskets.len(), par.meta.baskets.len(), "round {round}");
        for (a, b) in ser.meta.baskets.iter().zip(&par.meta.baskets) {
            assert_eq!(
                (a.branch_id, a.basket_index, a.first_entry, a.n_entries),
                (b.branch_id, b.basket_index, b.first_entry, b.n_entries),
                "round {round}"
            );
        }
        // Same decoded content.
        let ev_s = ser.read_all_events().unwrap();
        let ev_p = par.read_all_events().unwrap();
        assert_eq!(ev_s, ev_p, "round {round} (workers={workers} depth={queue_depth})");
        assert_eq!(ev_p, events, "round {round} vs source");
        std::fs::remove_file(&ser_path).ok();
        std::fs::remove_file(&par_path).ok();
    }
}

#[test]
fn single_worker_minimal_queue() {
    // Degenerate config must still work (backpressure path exercised hard).
    let events = synthetic::events(200, 42);
    let path = tmp_path("pipe", "degen");
    let (meta, _) = write_tree_parallel(
        &path,
        "Events",
        synthetic::schema(),
        Settings::new(Algorithm::Lz4, 1),
        256, // tiny baskets -> many jobs
        PipelineConfig { workers: 1, queue_depth: 1, dictionary: Vec::new() },
        events.iter().cloned(),
    )
    .unwrap();
    assert!(meta.baskets.len() > 50, "want many baskets, got {}", meta.baskets.len());
    let mut r = TreeReader::open(&path).unwrap();
    assert_eq!(r.read_all_events().unwrap(), events);
    std::fs::remove_file(&path).ok();
}

#[test]
fn many_workers_tiny_workload() {
    let events = synthetic::events(3, 7);
    let path = tmp_path("pipe", "tiny");
    let (_, _) = write_tree_parallel(
        &path,
        "Events",
        synthetic::schema(),
        Settings::new(Algorithm::Zstd, 3),
        1 << 20,
        PipelineConfig { workers: 16, queue_depth: 64, dictionary: Vec::new() },
        events.iter().cloned(),
    )
    .unwrap();
    let mut r = TreeReader::open(&path).unwrap();
    assert_eq!(r.read_all_events().unwrap(), events);
    std::fs::remove_file(&path).ok();
}

#[test]
fn pipeline_with_preconditioned_settings() {
    let events = synthetic::events(400, 11);
    let path = tmp_path("pipe", "precond");
    let settings = Settings::new(Algorithm::Lz4, 1).with_precond(Precond::BitShuffle(4));
    let (_, snap) = write_tree_parallel(
        &path,
        "Events",
        synthetic::schema(),
        settings,
        2048,
        PipelineConfig::default(),
        events.iter().cloned(),
    )
    .unwrap();
    assert!(snap.ratio() > 1.0, "ratio {}", snap.ratio());
    let mut r = TreeReader::open(&path).unwrap();
    assert_eq!(r.read_all_events().unwrap(), events);
    std::fs::remove_file(&path).ok();
}

#[test]
fn pipeline_with_dictionary() {
    // Dictionary flows: trained on sample baskets, carried in the file,
    // used on both write and read.
    let corpus = rootio::zstd::dict::synthetic_corpus(100, 400, 3);
    let dict = rootio::zstd::dict::train_from_corpus(&corpus, 4096);
    assert!(!dict.is_empty());
    let events: Vec<Vec<Value>> = corpus
        .iter()
        .map(|rec| vec![Value::AU8(rec.clone())])
        .collect();
    let branches = vec![rootio::rfile::BranchDef::new("rec", rootio::rfile::BranchType::VarU8)];
    let path = tmp_path("pipe", "dict");
    let (meta, _) = write_tree_parallel(
        &path,
        "Records",
        branches,
        Settings::new(Algorithm::Zstd, 6),
        1024,
        PipelineConfig { workers: 4, queue_depth: 8, dictionary: dict },
        events.iter().cloned(),
    )
    .unwrap();
    assert!(meta.dictionary_offset.is_some());
    let mut r = TreeReader::open(&path).unwrap();
    assert_eq!(r.read_all_events().unwrap(), events);
    std::fs::remove_file(&path).ok();
}
