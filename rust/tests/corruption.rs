// This target is linted by the CI clippy job; it shares the library's
// style-lint policy (see the lint-policy note in rust/src/lib.rs).

#![allow(unknown_lints, clippy::style)]

//! Failure-injection suite: randomized bit flips, truncations and
//! extensions of compressed records must NEVER panic, and must either
//! error out or (only where the format carries no checksum) produce output
//! that differs from the original. Silent false success is the only
//! forbidden outcome.
//!
//! Corpora and the attacked settings come from the shared testkit
//! (`mod common`): `PROP_SEED` reproduces a failed run, `PROP_ROUNDS`
//! caps the per-setting flip count (see rust/tests/common/mod.rs).

mod common;

use common::{corpus, prop_rounds, seeded, survey_settings};
use rootio::compression::{Algorithm, Engine, Settings};

#[test]
fn random_bit_flips_never_panic_or_lie() {
    let (mut rng, _guard) = seeded(0xBAD_B17);
    let mut engine = Engine::new();
    let mut flips = 0usize;
    let mut silent_ok = 0usize;
    let rounds = prop_rounds(30);
    for data in corpus(&mut rng) {
        for s in survey_settings() {
            let c = engine.compress(&data, &s);
            for _ in 0..rounds {
                let mut m = c.clone();
                let at = rng.range(0, m.len() - 1);
                m[at] ^= 1 << rng.range(0, 7);
                match engine.decompress(&m) {
                    Err(_) => {}
                    Ok(d) => {
                        if d == data {
                            // A flip that decodes identically can only be
                            // benign if it didn't change the *effective*
                            // stream (e.g. padding bits). Count and bound.
                            silent_ok += 1;
                        }
                    }
                }
                flips += 1;
            }
        }
    }
    // Every (corpus × setting) cell ran its full flip budget…
    assert_eq!(flips, 3 * survey_settings().len() * rounds);
    // …and padding-bit flips are rare; the overwhelming majority must be
    // caught (floor of 1 keeps a PROP_ROUNDS-reduced run meaningful).
    assert!(
        (silent_ok as f64) <= (0.02 * flips as f64).max(1.0),
        "{silent_ok}/{flips} corrupted streams decoded to the original"
    );
}

#[test]
fn truncations_never_panic() {
    let (mut rng, _guard) = seeded(0xBAD_717);
    let mut engine = Engine::new();
    for data in corpus(&mut rng) {
        for s in survey_settings() {
            let c = engine.compress(&data, &s);
            for frac in [0.0, 0.1, 0.5, 0.9, 0.99] {
                let cut = ((c.len() as f64) * frac) as usize;
                match engine.decompress(&c[..cut]) {
                    Err(_) => {}
                    Ok(d) => assert_ne!(d, data, "{} truncated at {cut} decoded fully", s.label()),
                }
            }
        }
    }
}

#[test]
fn appended_garbage_detected() {
    // Extra trailing bytes parse as a (bogus) next record and must error.
    let (mut rng, _guard) = seeded(0xBAD_A99);
    let mut engine = Engine::new();
    let data: Vec<u8> = (1u32..=10_000).flat_map(|i| i.to_be_bytes()).collect();
    for s in survey_settings() {
        let mut c = engine.compress(&data, &s);
        let tail_len = rng.range(1, 40);
        let tail = rng.bytes(tail_len);
        c.extend_from_slice(&tail);
        match engine.decompress(&c) {
            Err(_) => {}
            Ok(d) => assert_ne!(d, data, "{}: garbage tail silently ignored", s.label()),
        }
    }
}

#[test]
fn header_field_fuzzing() {
    // Directly attack the 10-byte record header: every mutated size field
    // must be handled gracefully.
    let (mut rng, _guard) = seeded(0xBADEAD);
    let mut engine = Engine::new();
    let data = rng.bytes(5_000);
    let c = engine.compress(&data, &Settings::new(Algorithm::Zstd, 5));
    for at in 0..10usize.min(c.len()) {
        for bit in 0..8 {
            let mut m = c.clone();
            m[at] ^= 1 << bit;
            let _ = engine.decompress(&m); // must not panic, any Result ok
        }
    }
}
