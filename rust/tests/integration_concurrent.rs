// This target is linted by the CI clippy job; it shares the library's
// style-lint policy (see the lint-policy note in rust/src/lib.rs).

#![allow(unknown_lints, clippy::style)]

//! Concurrent scan-server invariants (property-style, seeded): N scans
//! multiplexed onto one worker pool with a shared decoded-basket cache
//! must stay **byte-identical** to the serial [`TreeReader`] /
//! [`ParallelTreeReader`] oracles — for mixed projections, entry ranges,
//! and salvage scans, across the codec × preconditioner grid. On top of
//! oracle parity the suite pins the cache contract:
//!
//! * hits + misses == lookups, always;
//! * a warm identical re-scan decodes **zero** new baskets (and an 8-way
//!   identical concurrent wave decodes each basket exactly once — the
//!   single-flight registry, not just the cache);
//! * a starvation-size budget evicts constantly yet never corrupts a
//!   result;
//! * damaged baskets are never cached (every scan re-observes the damage);
//! * admission control bounds concurrently active scans at `max_scans`.
//!
//! Fixtures come from the shared testkit (`mod common`): `PROP_SEED`
//! reproduces a failed run, `PROP_ROUNDS` caps the grid/round counts (see
//! rust/tests/common/mod.rs).

mod common;

use common::{grid, prop_rounds, sample, seeded, tmp_path, write_sample_tree};
use rootio::compression::{Algorithm, Settings};
use rootio::coordinator::{
    ParallelTreeReader, Query, ReadAhead, ScanMode, ScanServer, ServeConfig,
};
use rootio::precond::Precond;
use rootio::rfile::{FaultSpec, IoBackend, IoConfig, RetryPolicy, TreeReader, Value};
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// A small server config that still exercises real concurrency.
fn cfg() -> ServeConfig {
    ServeConfig {
        workers: 3,
        max_scans: 8,
        queue_depth: 4,
        cache_bytes: 64 << 20,
        cache_shards: 4,
        io: IoConfig::default(),
    }
}

/// Write a two-file corpus (different event counts and seeds) and return
/// the paths. File stems — the corpus names — are `a` and `b`.
fn write_corpus(
    suite: &str,
    tag: &str,
    settings: Settings,
    basket: usize,
    seed: u64,
) -> Vec<PathBuf> {
    let pa = tmp_path(suite, &format!("{tag}_a.rfil"));
    let pb = tmp_path(suite, &format!("{tag}_b.rfil"));
    write_sample_tree(&pa, settings, 300, basket, seed);
    write_sample_tree(&pb, settings, 190, basket, seed ^ 0xFFFF);
    vec![pa, pb]
}

fn remove(paths: &[PathBuf]) {
    for p in paths {
        std::fs::remove_file(p).ok();
    }
}

/// Corpus names follow file stems: `..._a.rfil` → that stem.
fn stem(p: &PathBuf) -> String {
    p.file_stem().unwrap().to_str().unwrap().to_string()
}

#[test]
fn concurrent_mixed_scans_match_serial_oracles_across_grid() {
    let (mut rng, _guard) = seeded(0xC0C0);
    // Each grid cell gets its own corpus + server; the mixed query wave
    // (projection / entry range / all-branch / salvage) runs concurrently
    // and every result is checked against the serial oracle.
    let cells = sample(grid(), prop_rounds(8));
    for settings in cells {
        let paths = write_corpus("conc_grid", &format!("{settings:?}"), settings, 600, 0x5EED);
        let names: Vec<String> = paths.iter().map(stem).collect();
        let server = ScanServer::from_paths(&paths, cfg()).unwrap();

        // Serial oracles, computed up front on the main thread.
        let mut oracle_a = TreeReader::open(&paths[0]).unwrap();
        let mut oracle_b = TreeReader::open(&paths[1]).unwrap();
        let (ra, rb) = {
            let a0 = rng.range(0, 250) as u64;
            let a1 = a0 + rng.range(1, 60) as u64;
            let b0 = rng.range(0, 150) as u64;
            let b1 = b0 + rng.range(1, 50) as u64;
            ((a0, a1), (b0, b1))
        };
        let px_id = oracle_a.branch_id("px").unwrap();
        let tp_id = oracle_a.branch_id("Track_pt").unwrap();
        let nt_id = oracle_b.branch_id("nTrack").unwrap();
        let want_px = oracle_a.read_branch(px_id).unwrap();
        let want_tp = oracle_a.read_branch(tp_id).unwrap();
        let want_a_range = oracle_a.read_all_events_range(ra.0..ra.1).unwrap();
        let want_nt_range = oracle_b.read_range(nt_id, rb.0..rb.1).unwrap();
        let want_b_all = oracle_b.read_all_events().unwrap();

        let queries: Vec<(Query, Vec<Vec<Value>>)> = vec![
            (
                Query::project(&names[0], &["px", "Track_pt"]),
                vec![want_px.clone(), want_tp.clone()],
            ),
            (
                Query::all(&names[0]).entries(ra.0, ra.1),
                columns_of(&want_a_range),
            ),
            (
                Query::project(&names[1], &["nTrack"]).entries(rb.0, rb.1),
                vec![want_nt_range.clone()],
            ),
            (Query::all(&names[1]), columns_of(&want_b_all)),
            // Salvage mode over an undamaged file must equal strict.
            (
                Query::project(&names[0], &["Track_pt", "px"]).mode(ScanMode::Salvage),
                vec![want_tp, want_px],
            ),
        ];

        std::thread::scope(|scope| {
            for (i, (q, want)) in queries.iter().enumerate() {
                let server = &server;
                scope.spawn(move || {
                    let mut sq = server.query(q).unwrap();
                    assert!(
                        sq.plan().is_monotonic_sweep(),
                        "query {i} plan not offset-sorted under {settings:?}"
                    );
                    let got = sq.read_columns().unwrap();
                    assert_eq!(&got, want, "query {i} diverged under {settings:?}");
                    assert!(sq.gaps().is_empty(), "clean file produced gaps");
                });
            }
        });

        let cs = server.cache_stats();
        assert_eq!(cs.hits + cs.misses, cs.lookups, "cache accounting under {settings:?}");
        remove(&paths);
    }
}

/// Transpose events (rows) into per-branch columns, the shape
/// `read_columns` returns for an all-branch query.
fn columns_of(events: &[Vec<Value>]) -> Vec<Vec<Value>> {
    if events.is_empty() {
        return Vec::new();
    }
    let n = events[0].len();
    (0..n).map(|b| events.iter().map(|e| e[b].clone()).collect()).collect()
}

#[test]
fn all_branch_range_surfaces_agree() {
    let (mut rng, _guard) = seeded(0xA11B);
    let path = tmp_path("conc_allrange", "f.rfil");
    let settings = Settings::new(Algorithm::Zstd, 5).with_precond(Precond::Shuffle(4));
    write_sample_tree(&path, settings, 257, 700, 0xF00D);
    let mut serial = TreeReader::open(&path).unwrap();
    let par = ParallelTreeReader::open(&path, ReadAhead::with_workers(2)).unwrap();
    let all = serial.read_all_events().unwrap();
    for _ in 0..prop_rounds(6) {
        let a = rng.range(0, 257) as u64;
        let b = a + rng.range(0, 300) as u64; // may overshoot: clamped
        let want: Vec<Vec<Value>> =
            all[a.min(257) as usize..b.min(257) as usize].to_vec();
        assert_eq!(serial.read_all_events_range(a..b).unwrap(), want, "serial [{a},{b})");
        assert_eq!(par.read_all_events_range(a..b).unwrap(), want, "parallel [{a},{b})");
        let mut proj = par.project_all_range(a..b).unwrap();
        assert_eq!(proj.read_columns().unwrap(), columns_of(&want), "projection [{a},{b})");
    }
    // Degenerate windows: empty and fully out of range.
    assert!(serial.read_all_events_range(5..5).unwrap().is_empty());
    assert!(par.read_all_events_range(400..900).unwrap().is_empty());
    std::fs::remove_file(&path).ok();
}

#[test]
fn warm_identical_waves_decode_each_basket_exactly_once() {
    let paths = write_corpus(
        "conc_warm",
        "w",
        Settings::new(Algorithm::Lz4, 1).with_precond(Precond::BitShuffle(4)),
        512,
        0xBEEF,
    );
    let names: Vec<String> = paths.iter().map(stem).collect();
    let server = ScanServer::from_paths(&paths, cfg()).unwrap();
    let unique_baskets = server.files()[0].meta.baskets.len() as u64;
    assert!(unique_baskets > 4, "fixture too small to be interesting");

    // One 8-way wave of IDENTICAL all-branch scans over file `a`. The
    // single-flight registry must collapse them: each basket decodes
    // exactly once even though eight scans race for it cold.
    let wave = |expect_all_cached: bool| {
        std::thread::scope(|scope| {
            for _ in 0..8 {
                let server = &server;
                let name = names[0].clone();
                scope.spawn(move || {
                    let mut sq = server.query(&Query::all(&name)).unwrap();
                    let cols = sq.read_columns().unwrap();
                    assert_eq!(cols.len(), server.files()[0].meta.branches.len());
                    let st = sq.stats();
                    assert_eq!(
                        st.baskets_decoded + st.baskets_from_cache + st.baskets_coalesced,
                        unique_baskets,
                        "every basket accounted to exactly one source"
                    );
                    if expect_all_cached {
                        assert_eq!(st.baskets_decoded, 0, "warm scan decoded");
                        assert_eq!(st.baskets_coalesced, 0, "warm scan coalesced");
                        assert_eq!(st.baskets_from_cache, unique_baskets);
                        assert!(st.bytes_from_cache > 0);
                    }
                });
            }
        });
    };

    wave(false);
    let after_cold = server.metrics_snapshot();
    assert_eq!(
        after_cold.baskets, unique_baskets,
        "cold 8-way wave must decode each basket exactly once (single-flight)"
    );

    wave(true);
    let after_warm = server.metrics_snapshot();
    assert_eq!(after_warm.baskets, unique_baskets, "warm wave decoded new baskets");
    assert!(after_warm.cache_hits >= 8 * unique_baskets, "warm wave should be all hits");

    let cs = server.cache_stats();
    assert_eq!(cs.hits + cs.misses, cs.lookups);
    assert_eq!(cs.evictions, 0, "budget is ample; nothing should be evicted");
    remove(&paths);
}

#[test]
fn starvation_budget_evicts_constantly_but_never_corrupts() {
    let paths = write_corpus(
        "conc_tiny",
        "t",
        Settings::new(Algorithm::Zlib, 6),
        512,
        0xD1E7,
    );
    let names: Vec<String> = paths.iter().map(stem).collect();
    // A cache too small to hold more than ~one basket: every scan thrashes
    // it, evictions fire constantly, and results must still be exact
    // (Arc refcounts keep in-flight payloads alive across eviction).
    let server = ScanServer::from_paths(
        &paths,
        ServeConfig { cache_bytes: 4096, cache_shards: 1, ..cfg() },
    )
    .unwrap();
    let mut oracle = TreeReader::open(&paths[0]).unwrap();
    let want = columns_of(&oracle.read_all_events().unwrap());
    std::thread::scope(|scope| {
        for _ in 0..4 {
            let server = &server;
            let name = names[0].clone();
            let want = &want;
            scope.spawn(move || {
                let mut sq = server.query(&Query::all(&name)).unwrap();
                assert_eq!(&sq.read_columns().unwrap(), want, "tiny-budget scan diverged");
            });
        }
    });
    let cs = server.cache_stats();
    assert_eq!(cs.hits + cs.misses, cs.lookups);
    assert!(
        cs.evictions > 0 || cs.rejected > 0,
        "a 4 KiB budget must evict or reject under this workload: {cs:?}"
    );
    remove(&paths);
}

#[test]
fn damaged_baskets_are_never_cached() {
    let path = tmp_path("conc_damage", "d.rfil");
    // LZ4 baskets carry a CRC-32 content checksum, so an interior payload
    // flip is detected deterministically.
    let meta = write_sample_tree(&path, Settings::new(Algorithm::Lz4, 9), 300, 600, 0xDA);
    let victim = meta.baskets[meta.baskets.len() / 2];
    let mut bytes = std::fs::read(&path).unwrap();
    // Record layout at loc.file_offset: u32 len, u8 kind, payload.
    let target = victim.file_offset as usize + 5 + (victim.compressed_len as usize) / 2;
    bytes[target] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();

    // Salvage oracle: the parallel projection reader on the damaged file.
    let par = ParallelTreeReader::open(&path, ReadAhead::with_workers(2)).unwrap();
    let branch_names: Vec<String> = par.meta.branches.iter().map(|b| b.name.clone()).collect();
    let name_refs: Vec<&str> = branch_names.iter().map(|s| s.as_str()).collect();
    let mut oracle = par.project_salvage(&name_refs).unwrap();
    let want = oracle.read_columns().unwrap();
    let want_gaps = oracle.gaps().to_vec();
    assert!(!want_gaps.is_empty(), "flip did not damage the victim basket");

    let server = ScanServer::from_paths(&[path.clone()], cfg()).unwrap();
    let run = |server: &ScanServer| {
        let mut sq = server
            .query(&Query::all(&stem(&path)).mode(ScanMode::Salvage))
            .unwrap();
        let got = sq.read_columns().unwrap();
        assert_eq!(got, want, "salvage columns diverged from oracle");
        assert_eq!(sq.gaps(), &want_gaps[..], "salvage gaps diverged from oracle");
        assert_eq!(sq.damage().len(), 1);
        sq.stats()
    };
    let cold = run(&server);
    let warm = run(&server);
    let total = meta.baskets.len() as u64;
    // Cold pass: every intact basket decoded once, the damaged one failed.
    assert_eq!(cold.baskets_decoded, total - 1);
    // Warm pass: intact baskets come from cache; the damaged basket was
    // NOT cached, so it is re-read and fails again (not served stale).
    assert_eq!(warm.baskets_from_cache, total - 1, "damaged basket leaked into cache");
    assert_eq!(warm.baskets_decoded, 0);
    // A strict query over the same file still fails outright.
    let mut strict = server.query(&Query::all(&stem(&path))).unwrap();
    assert!(strict.read_columns().is_err(), "strict scan must refuse damage");
    std::fs::remove_file(&path).ok();
}

#[test]
fn admission_control_bounds_active_scans() {
    let paths = write_corpus(
        "conc_admit",
        "m",
        Settings::new(Algorithm::Zstd, 1),
        512,
        0xAD31,
    );
    let names: Vec<String> = paths.iter().map(stem).collect();
    let server = ScanServer::from_paths(
        &paths,
        ServeConfig { max_scans: 2, ..cfg() },
    )
    .unwrap();
    let mut oracle = TreeReader::open(&paths[1]).unwrap();
    let want = columns_of(&oracle.read_all_events().unwrap());
    std::thread::scope(|scope| {
        for _ in 0..6 {
            let server = &server;
            let name = names[1].clone();
            let want = &want;
            scope.spawn(move || {
                let mut sq = server.query(&Query::all(&name)).unwrap();
                assert_eq!(&sq.read_columns().unwrap(), want);
            });
        }
    });
    assert!(
        server.peak_active() <= 2,
        "admission control violated: peak {} > max_scans 2",
        server.peak_active()
    );
    assert!(server.peak_active() >= 1);
    remove(&paths);
}

/// Satellite regression (PR 10): a high-latency remote-sim file must not
/// stall a concurrent zero-latency scan. The scheduler banks the remote
/// wait ([`RemotePacing::Deferred`] under the hood) and charges it to the
/// slow query's own deliveries — workers never sleep, so the fast file's
/// query runs at local-disk speed and its queue-wait stays flat.
#[test]
fn slow_remote_file_does_not_stall_concurrent_local_scan() {
    let pa = tmp_path("conc_iso", "fast_a.rfil");
    let pb = tmp_path("conc_iso", "slow_b.rfil");
    let settings = Settings::new(Algorithm::Lz4, 1);
    write_sample_tree(&pa, settings, 200, 512, 0xFA);
    let meta_b = write_sample_tree(&pb, settings, 200, 512, 0xFB);
    // Floor the slow query's wall time: with 3 workers × window 2 the
    // remote pipeline moves ≤ 6 requests per latency period, so with ≥ 24
    // baskets some worker carries ≥ 8 of them — ≥ 4 full 25 ms periods on
    // one chain, regardless of machine speed.
    assert!(meta_b.baskets.len() >= 24, "fixture too small: {}", meta_b.baskets.len());
    let mut slow_io = IoConfig::for_backend(IoBackend::RemoteSim);
    slow_io.latency = Duration::from_millis(25);
    let server = ScanServer::from_paths_with_io(
        &[(pa.clone(), IoConfig::default()), (pb.clone(), slow_io)],
        // Cold reads only (no cache) and a narrow window so the latency
        // model, not the cache, dominates the slow file.
        ServeConfig { cache_bytes: 0, queue_depth: 2, ..cfg() },
    )
    .unwrap();
    let mut oracle_a = TreeReader::open(&pa).unwrap();
    let want_a = columns_of(&oracle_a.read_all_events().unwrap());
    let mut oracle_b = TreeReader::open(&pb).unwrap();
    let want_b = columns_of(&oracle_b.read_all_events().unwrap());

    let (fast, slow) = std::thread::scope(|scope| {
        let fast = {
            let server = &server;
            let want_a = &want_a;
            scope.spawn(move || {
                let t0 = Instant::now();
                let mut sq = server.query(&Query::all("fast_a")).unwrap();
                assert_eq!(&sq.read_columns().unwrap(), want_a, "fast file diverged");
                (t0.elapsed(), sq.stats())
            })
        };
        let slow = {
            let server = &server;
            let want_b = &want_b;
            scope.spawn(move || {
                let t0 = Instant::now();
                let mut sq = server.query(&Query::all("slow_b")).unwrap();
                assert_eq!(&sq.read_columns().unwrap(), want_b, "slow file diverged");
                (t0.elapsed(), sq.stats())
            })
        };
        (fast.join().unwrap(), slow.join().unwrap())
    });
    let (fast_wall, fast_stats) = fast;
    let (slow_wall, _slow_stats) = slow;
    assert!(
        slow_wall >= Duration::from_millis(100),
        "latency model never charged the slow query: {slow_wall:?}"
    );
    assert!(
        fast_wall * 3 < slow_wall,
        "zero-latency scan degraded by the concurrent slow file: fast {fast_wall:?} vs slow {slow_wall:?}"
    );
    assert!(
        fast_stats.queue_wait < Duration::from_millis(50),
        "fast query queued behind the slow file: {:?}",
        fast_stats.queue_wait
    );
    remove(&[pa, pb]);
}

/// Satellite regression (PR 10): per-query `read_retries` must not
/// double-count when the server opens the same file for several queries.
/// Counters are per source chain and charged per decode job, so each
/// query sees exactly its own retries and the server total is their sum.
#[test]
fn per_query_retry_counters_do_not_double_count() {
    let pf = tmp_path("conc_retry", "faulty.rfil");
    let pc = tmp_path("conc_retry", "clean.rfil");
    write_sample_tree(&pf, Settings::new(Algorithm::Zstd, 1), 250, 512, 0x1F);
    write_sample_tree(&pc, Settings::new(Algorithm::Zstd, 1), 250, 512, 0x2C);
    let faulty_io = IoConfig {
        faults: Some(FaultSpec {
            seed: 9,
            transient: 0.4,
            max_consecutive: 2,
            ..FaultSpec::default()
        }),
        retry: RetryPolicy {
            max_attempts: 4, // > max_consecutive: recovery guaranteed
            base_delay: Duration::ZERO,
            backoff: 1.0,
            max_delay: Duration::ZERO,
        },
        ..IoConfig::default()
    };
    let server = ScanServer::from_paths_with_io(
        &[(pf.clone(), faulty_io), (pc.clone(), IoConfig::default())],
        // No cache: every pass re-reads, so the fault schedule fires on
        // both faulty queries.
        ServeConfig { cache_bytes: 0, ..cfg() },
    )
    .unwrap();
    let run = |name: &str| {
        let mut sq = server.query(&Query::all(name)).unwrap();
        sq.read_columns().unwrap();
        sq.stats()
    };
    let faulty_first = run("faulty");
    let clean = run("clean");
    let faulty_second = run("faulty");
    assert!(faulty_first.read_retries > 0, "fault schedule never fired on pass 1");
    assert!(faulty_second.read_retries > 0, "fault schedule never fired on pass 2");
    assert_eq!(
        clean.read_retries, 0,
        "clean file's query was billed another query's retries"
    );
    assert_eq!(
        server.metrics_snapshot().read_retries,
        faulty_first.read_retries + faulty_second.read_retries,
        "per-query retry counters must partition the server total"
    );
    remove(&[pf, pc]);
}

#[test]
fn empty_window_queries_return_without_blocking() {
    let paths = write_corpus("conc_empty", "e", Settings::new(Algorithm::None, 0), 512, 0xE);
    let names: Vec<String> = paths.iter().map(stem).collect();
    let server = ScanServer::from_paths(&paths, cfg()).unwrap();
    // An empty entry window produces a zero-basket plan; it must complete
    // immediately (even if admission were saturated) with empty columns.
    let mut sq = server.query(&Query::all(&names[0]).entries(7, 7)).unwrap();
    let cols = sq.read_columns().unwrap();
    assert!(cols.iter().all(|c| c.is_empty()));
    let st = sq.stats();
    assert_eq!(st.baskets_decoded + st.baskets_from_cache + st.baskets_coalesced, 0);
    remove(&paths);
}
