// This target is linted by the CI clippy job; it shares the library's
// style-lint policy (see the lint-policy note in rust/src/lib.rs).

#![allow(unknown_lints, clippy::style)]

//! Interop tests: our from-scratch zlib against the independent `flate2`
//! implementation (miniz_oxide backend).
//!
//! Both directions must hold for every level and both tuning flavors:
//!  * bytes we compress must decompress correctly under flate2;
//!  * bytes flate2 compresses must decompress correctly under us.
//! This is the strongest evidence our RFC 1950/1951 implementation is
//! format-correct, not merely self-consistent.

use flate2::read::ZlibDecoder;
use flate2::write::ZlibEncoder;
use flate2::Compression;
use rootio::deflate::{zlib_compress, zlib_decompress, Flavor};
use rootio::util::rng::Rng;
use std::io::{Read, Write};

const MAX: usize = 256 << 20;

fn flate2_compress(data: &[u8], level: u32) -> Vec<u8> {
    let mut enc = ZlibEncoder::new(Vec::new(), Compression::new(level));
    enc.write_all(data).unwrap();
    enc.finish().unwrap()
}

fn flate2_decompress(data: &[u8]) -> std::io::Result<Vec<u8>> {
    let mut dec = ZlibDecoder::new(data);
    let mut out = Vec::new();
    dec.read_to_end(&mut out)?;
    Ok(out)
}

fn corpus() -> Vec<Vec<u8>> {
    let mut rng = Rng::new(0x1207);
    let mut corpus: Vec<Vec<u8>> = vec![
        vec![],
        b"x".to_vec(),
        b"aaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaa".to_vec(),
        vec![0u8; 200_000],
    ];
    // ROOT-offset-array-like: monotone big-endian u32.
    corpus.push((0u32..30_000).flat_map(|i| (i * 7).to_be_bytes()).collect());
    // Text-like.
    let mut text = Vec::new();
    while text.len() < 120_000 {
        text.extend_from_slice(
            b"The compressed baskets entries present a number of advanced \
              compression or decompression possibilities. ",
        );
    }
    corpus.push(text);
    // Pure noise.
    corpus.push(rng.bytes(150_000));
    // Mixed basket-like payload: floats + ints + runs.
    let mut mixed = Vec::new();
    for i in 0..20_000u32 {
        mixed.extend_from_slice(&(i as f32 * 0.5).to_be_bytes());
        if i % 16 == 0 {
            mixed.extend_from_slice(&[0u8; 24]);
        }
        if i % 97 == 0 {
            mixed.extend_from_slice(&rng.bytes(8));
        }
    }
    corpus.push(mixed);
    corpus
}

#[test]
fn ours_to_flate2_all_levels() {
    for data in corpus() {
        for flavor in [Flavor::Reference, Flavor::Cloudflare] {
            for level in 0..=9u8 {
                let c = zlib_compress(&data, flavor, level);
                let d = flate2_decompress(&c).unwrap_or_else(|e| {
                    panic!("flate2 rejected our stream ({flavor:?} L{level}, {} bytes): {e}", data.len())
                });
                assert_eq!(d, data, "{flavor:?} L{level}");
            }
        }
    }
}

#[test]
fn flate2_to_ours_all_levels() {
    for data in corpus() {
        for level in 0..=9u32 {
            let c = flate2_compress(&data, level);
            let d = zlib_decompress(&c, data.len(), MAX)
                .unwrap_or_else(|e| panic!("we rejected flate2 stream (L{level}): {e}"));
            assert_eq!(d, data, "flate2 L{level}");
        }
    }
}

#[test]
fn fuzz_cross_roundtrip() {
    let mut rng = Rng::new(0xF1A7E2);
    for round in 0..40 {
        let n = rng.range(0, 60_000);
        let mut data = Vec::with_capacity(n);
        while data.len() < n {
            match rng.range(0, 3) {
                0 => {
                    let b = (rng.next_u64() & 0xFF) as u8;
                    let run = rng.range(1, 500);
                    data.extend(std::iter::repeat(b).take(run));
                }
                1 => {
                    let v = rng.next_u32();
                    data.extend_from_slice(&v.to_be_bytes());
                }
                2 => data.extend_from_slice(b"NanoAOD_Muon_pt"),
                _ => {
                    let k = rng.range(1, 100);
                    let b = rng.bytes(k);
                    data.extend_from_slice(&b);
                }
            }
        }
        data.truncate(n);
        let level = (round % 10) as u8;
        let flavor = if round % 2 == 0 { Flavor::Reference } else { Flavor::Cloudflare };
        // ours -> flate2
        let c = zlib_compress(&data, flavor, level);
        assert_eq!(flate2_decompress(&c).unwrap(), data);
        // flate2 -> ours
        let c2 = flate2_compress(&data, level as u32);
        assert_eq!(zlib_decompress(&c2, n, MAX).unwrap(), data);
    }
}

#[test]
fn checksum_cross_validation() {
    // Our crc32 backends vs the independent crc32fast crate.
    let mut rng = Rng::new(0xCC);
    for _ in 0..20 {
        let n = rng.range(0, 100_000);
        let data = rng.bytes(n);
        let theirs = {
            let mut h = crc32fast::Hasher::new();
            h.update(&data);
            h.finalize()
        };
        for backend in [
            rootio::checksum::crc32::Backend::Bitwise,
            rootio::checksum::crc32::Backend::Table,
            rootio::checksum::crc32::Backend::Slice8,
        ] {
            assert_eq!(rootio::checksum::crc32_with(&data, backend), theirs, "n={n}");
        }
    }
}
