// This target is linted by the CI clippy job; it shares the library's
// style-lint policy (see the lint-policy note in rust/src/lib.rs).

#![allow(unknown_lints, clippy::style)]

//! Read-pipeline invariants (property-style, seeded): for any worker count
//! (1/2/4), queue depth, basket size, codec, and preconditioner, the
//! parallel reader must be **byte-identical** to the serial
//! [`rootio::rfile::TreeReader`] oracle — including which files it
//! *rejects*. Decompression parallelism must never change what a file
//! decodes to, and must never accept bytes the serial reader refuses
//! (truncation, corrupted checksums, identity mismatches).
//!
//! Fixtures come from the shared testkit (`mod common`): `PROP_SEED`
//! reproduces a failed run, `PROP_ROUNDS` caps the grid/round counts (see
//! rust/tests/common/mod.rs).

mod common;

use common::{grid, prop_rounds, sample, seeded, tmp_path, write_sample_tree};
use rootio::compression::{Algorithm, Settings};
use rootio::coordinator::{ParallelTreeReader, ReadAhead};
use rootio::gen::synthetic;
use rootio::precond::Precond;
use rootio::rfile::{write_tree_serial, TreeReader, Value};

#[test]
fn parallel_read_equals_serial_oracle_across_grid() {
    let (mut rng, _guard) = seeded(0x0EAD);
    // Small event counts keep the whole grid (32 settings × 3 worker
    // counts) fast; random basket sizes vary the basket structure.
    let events = synthetic::events(120, rng.next_u64());
    let settings_grid = sample(grid(), prop_rounds(usize::MAX));
    for (i, settings) in settings_grid.into_iter().enumerate() {
        let basket_size = rng.range(256, 8192);
        let path = tmp_path("rpipe_prop", &format!("grid{i}"));
        write_tree_serial(
            &path,
            "Events",
            synthetic::schema(),
            settings,
            basket_size,
            events.iter().cloned(),
        )
        .unwrap();

        // Serial oracle.
        let mut serial = TreeReader::open(&path).unwrap();
        let oracle_events = serial.read_all_events().unwrap();
        assert_eq!(oracle_events, events, "{} oracle", settings.label());

        for workers in [1usize, 2, 4] {
            let depth = rng.range(1, 8);
            let par = ParallelTreeReader::open(&path, ReadAhead { workers, depth }).unwrap();

            // Per-basket content identity (data bytes + offsets + counts).
            let mut scan = par.scan(par.meta.baskets.clone()).unwrap();
            for loc in &par.meta.baskets {
                let (ploc, content) = scan.next_basket().unwrap().unwrap();
                assert_eq!((ploc.branch_id, ploc.basket_index), (loc.branch_id, loc.basket_index));
                let oracle = serial.read_basket(loc).unwrap();
                assert_eq!(content, oracle, "{} w={workers} basket {:?}", settings.label(), loc);
                scan.recycle(content);
            }
            assert!(scan.next_basket().is_none());

            // Whole-file identity through the high-level APIs.
            assert_eq!(
                par.read_all_events().unwrap(),
                oracle_events,
                "{} w={workers} d={depth}",
                settings.label()
            );
        }
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn per_branch_reads_match_serial() {
    let path = tmp_path("rpipe_prop", "branch");
    write_sample_tree(
        &path,
        Settings::new(Algorithm::Lz4, 1).with_precond(Precond::BitShuffle(4)),
        400,
        2048,
        0xB0B,
    );
    let mut serial = TreeReader::open(&path).unwrap();
    // The rfile-level API: upgrade the already-open serial reader.
    let par = serial.read_ahead(ReadAhead::with_workers(3));
    let n_branches = serial.meta.branches.len();
    for b in 0..n_branches as u32 {
        let oracle: Vec<Value> = serial.read_branch(b).unwrap();
        assert_eq!(par.read_branch(b).unwrap(), oracle, "branch {b}");
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn truncated_files_rejected_in_parity() {
    let path = tmp_path("rpipe_prop", "trunc");
    write_sample_tree(&path, Settings::new(Algorithm::Zstd, 5), 150, 1024, 0x7777);
    let bytes = std::fs::read(&path).unwrap();
    let cut_path = tmp_path("rpipe_prop", "trunc_cut");
    // Cuts across the whole file: header, first baskets, mid-file, trailer.
    let cuts = [0usize, 3, 6, 40, bytes.len() / 3, bytes.len() / 2, bytes.len() - 9, bytes.len() - 1];
    for &cut in &cuts {
        std::fs::write(&cut_path, &bytes[..cut]).unwrap();
        let serial_result = TreeReader::open(&cut_path).and_then(|mut r| r.read_all_events());
        let parallel_result = ParallelTreeReader::open(&cut_path, ReadAhead::with_workers(2))
            .and_then(|r| r.read_all_events());
        match (serial_result, parallel_result) {
            (Ok(s), Ok(p)) => assert_eq!(s, p, "cut {cut}"),
            (Err(_), Err(_)) => {}
            (s, p) => panic!(
                "cut {cut}: serial {} but parallel {}",
                if s.is_ok() { "accepted" } else { "rejected" },
                if p.is_ok() { "accepted" } else { "rejected" },
            ),
        }
    }
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&cut_path).ok();
}

#[test]
fn corrupted_bytes_rejected_in_parity() {
    // Byte flips anywhere in the file (basket payloads, record framing,
    // checksums, metadata): the parallel reader must agree with the serial
    // oracle on accept/reject, and on decoded values where both accept.
    // LZ4 carries the CRC-32 content checksum, so flips inside LZ4 basket
    // payloads exercise the checksum-rejection lane specifically.
    let path = tmp_path("rpipe_prop", "corrupt");
    write_sample_tree(&path, Settings::new(Algorithm::Lz4, 1), 150, 1024, 0xC0C0);
    let bytes = std::fs::read(&path).unwrap();
    let (mut rng, _guard) = seeded(0xBADF);
    let flip_path = tmp_path("rpipe_prop", "corrupt_flip");
    let mut serial_rejects = 0;
    let rounds = prop_rounds(40) as u32;
    for round in 0..rounds {
        let pos = rng.range(6, bytes.len() - 1); // past the RFIL header magic
        let mut corrupted = bytes.clone();
        corrupted[pos] ^= 1u8 << (round % 8);
        std::fs::write(&flip_path, &corrupted).unwrap();
        let serial_result = TreeReader::open(&flip_path).and_then(|mut r| r.read_all_events());
        let parallel_result = ParallelTreeReader::open(&flip_path, ReadAhead::with_workers(2))
            .and_then(|r| r.read_all_events());
        match (serial_result, parallel_result) {
            (Ok(s), Ok(p)) => assert_eq!(s, p, "flip at {pos}"),
            (Err(_), Err(_)) => serial_rejects += 1,
            (s, p) => panic!(
                "flip at {pos}: serial {} but parallel {}",
                if s.is_ok() { "accepted" } else { "rejected" },
                if p.is_ok() { "accepted" } else { "rejected" },
            ),
        }
    }
    // Sanity: the corpus actually exercised the reject lane. (With a
    // PROP_ROUNDS-reduced run a streak of benign flips is conceivable, so
    // only the full-round run asserts it.)
    assert!(
        serial_rejects > 0 || rounds < 40,
        "no corruption was ever rejected in {rounds} rounds"
    );
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&flip_path).ok();
}

#[test]
fn checksum_corruption_in_lz4_basket_rejected_by_both() {
    // Surgical test for the off-critical-path checksum verification: flip a
    // byte inside the *stored CRC-32* of the first LZ4 basket frame. The
    // decompressed bytes are untouched, so only the checksum comparison can
    // catch it — both readers must reject.
    let path = tmp_path("rpipe_prop", "crc");
    write_sample_tree(&path, Settings::new(Algorithm::Lz4, 1), 200, 4096, 0x5EED);
    let serial = TreeReader::open(&path).unwrap();
    // Find a basket whose first span was actually LZ4-compressed (tag
    // "L4"), not stored raw: parse the basket framing (five uvarints —
    // branch_id, basket_index, n_entries, data_len, n_offsets) to land
    // exactly on the first span header, per docs/FORMAT.md §5–6.
    let mut bytes = std::fs::read(&path).unwrap();
    let mut patched = false;
    for loc in serial.meta.baskets.clone() {
        // Record layout at loc.file_offset: u32 len, u8 kind, payload.
        let payload_start = loc.file_offset as usize + 5;
        let payload_end = payload_start + loc.compressed_len as usize;
        let payload = &bytes[payload_start..payload_end];
        let mut pos = 0usize;
        for _ in 0..5 {
            let (_, n) = rootio::util::varint::get_uvarint(&payload[pos..]).unwrap();
            pos += n;
        }
        // Span header: 2-byte tag, level, 3+3-byte sizes, precond byte;
        // the LZ4 CRC-32 is the first 4 bytes of the span body.
        if payload.get(pos..pos + 2) == Some(b"L4") {
            let crc_pos = payload_start + pos + 10;
            assert!(crc_pos + 4 <= payload_end, "span body shorter than its checksum");
            bytes[crc_pos] ^= 0xFF;
            patched = true;
            break;
        }
    }
    assert!(patched, "no LZ4-compressed span found to patch");
    let crc_path = tmp_path("rpipe_prop", "crc_flip");
    std::fs::write(&crc_path, &bytes).unwrap();
    let serial_result = TreeReader::open(&crc_path).and_then(|mut r| r.read_all_events());
    let parallel_result = ParallelTreeReader::open(&crc_path, ReadAhead::with_workers(2))
        .and_then(|r| r.read_all_events());
    assert!(serial_result.is_err(), "serial reader accepted a corrupted checksum");
    assert!(parallel_result.is_err(), "parallel reader accepted a corrupted checksum");
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&crc_path).ok();
}
