#!/usr/bin/env python3
"""Fill ROADMAP.md's measured-numbers block from a BENCH_codecs.json.

Usage:
    python3 python/roadmap_fill.py BENCH_codecs.json [ROADMAP.md] [-o OUT.md]

The PR-1/PR-2/PR-3 perf-trajectory sections of ROADMAP.md were authored in
containers without a Rust toolchain, so their speedup claims point at the
bench artifact instead of quoting numbers. This script renders the
artifact's `fast_path_speedups`, `entropy`, `read_pipeline`, `projection`,
`projection_range`, `concurrent`, `repack`, and `io_backends` sections as
markdown tables into the block delimited by

    <!-- BENCH_NUMBERS_BEGIN -->
    ...
    <!-- BENCH_NUMBERS_END -->

CI runs it after regenerating the bench JSON and uploads the result as
`ROADMAP.filled.md` in the BENCH_codecs artifact; committing that file
back as ROADMAP.md (or copying the table) is the one-command way to land
real measured numbers. Exits 1 if the markers are missing, 2 if the JSON
fails the bench_diff schema check.
"""

import argparse
import sys

# bench_diff sits next to this script; the script's own directory is on
# sys.path automatically when run as `python3 python/roadmap_fill.py`.
from bench_diff import SchemaError, load, validate  # noqa: E402

BEGIN = "<!-- BENCH_NUMBERS_BEGIN -->"
END = "<!-- BENCH_NUMBERS_END -->"


def fmt(v, suffix=""):
    return f"{v:.1f}{suffix}" if isinstance(v, (int, float)) else "—"


def render(doc):
    lines = []
    quick = doc.get("quick_mode")
    prov = doc.get("generated_by", "?")
    lines.append(f"Measured numbers (source: `{prov}`"
                 + (", BENCH_QUICK smoke run" if quick else "") + "):")
    lines.append("")
    rows = doc.get("fast_path_speedups") or []
    have = [r for r in rows if isinstance(r.get("speedup"), (int, float))]
    if have:
        lines.append("| fast path | payload | fast MB/s | naive MB/s | speedup |")
        lines.append("|---|---|---:|---:|---:|")
        for r in rows:
            lines.append(
                f"| {r.get('name','?')} | {r.get('payload','?')} | "
                f"{fmt(r.get('fast_MBps'))} | {fmt(r.get('reference_MBps'))} | "
                f"{fmt(r.get('speedup'), 'x')} |"
            )
    else:
        lines.append("*(artifact is still a placeholder — fast-path MB/s "
                     "fields are null; re-run from a real bench artifact)*")
    entropy = doc.get("entropy") or []
    have_entropy = [r for r in entropy
                    if isinstance(r.get("encode_MBps"), (int, float))]
    if entropy:
        lines.append("")
        lines.append("Entropy lanes (fse2 = dual-state FSE, fse4 = quad-state FSE, "
                     "huff0 = 4-stream Huffman literals; coder throughput, "
                     "tables prebuilt for FSE):")
        lines.append("")
        if have_entropy:
            lines.append("| lane | payload | ratio | encode MB/s | decode MB/s |")
            lines.append("|---|---|---:|---:|---:|")
            for r in entropy:
                lines.append(
                    f"| {r.get('lane','?')} | {r.get('payload','?')} | "
                    f"{fmt(r.get('ratio'))} | {fmt(r.get('encode_MBps'))} | "
                    f"{fmt(r.get('decode_MBps'))} |"
                )
        else:
            lines.append("*(entropy lanes present but unfilled)*")
    reads = doc.get("read_pipeline") or []
    have_reads = [r for r in reads if isinstance(r.get("MBps"), (int, float))]
    if reads:
        lines.append("")
        lines.append("Read-pipeline scaling (uncompressed MB/s of a whole-file read):")
        lines.append("")
        if have_reads:
            lines.append("| setting | serial | 1 worker | 2 workers | 4 workers |")
            lines.append("|---|---:|---:|---:|---:|")
            by_setting = {}
            for r in reads:
                by_setting.setdefault(r.get("setting", "?"), {})[r.get("workers")] = r.get("MBps")
            for setting, cells in by_setting.items():
                lines.append(
                    f"| {setting} | " + " | ".join(fmt(cells.get(w)) for w in (0, 1, 2, 4)) + " |"
                )
        else:
            lines.append("*(read-pipeline lanes present but unfilled)*")
    projs = doc.get("projection") or []
    have_projs = [r for r in projs if isinstance(r.get("MBps"), (int, float))]
    if projs:
        lines.append("")
        lines.append("Columnar projection (uncompressed MB/s of the projected branches; "
                     "serial = k independent `read_branch` sweeps, pipeline lanes at 4 workers):")
        lines.append("")
        if have_projs:
            lines.append("| projection | serial | offset-sorted | submission-order |")
            lines.append("|---|---:|---:|---:|")
            by_branches = {}
            for r in projs:
                by_branches.setdefault(r.get("branches", "?"), {})[r.get("order")] = r.get("MBps")
            for branches, cells in by_branches.items():
                lines.append(
                    f"| {branches} | "
                    + " | ".join(fmt(cells.get(o)) for o in ("serial", "offset", "submission"))
                    + " |"
                )
        else:
            lines.append("*(projection lanes present but unfilled)*")
    pranges = doc.get("projection_range") or []
    have_pranges = [r for r in pranges if isinstance(r.get("MBps"), (int, float))]
    if pranges:
        lines.append("")
        lines.append("Entry-range projection (2-branch NanoAOD read at 4 workers; "
                     "MB/s over the sliced plan's decoded bytes):")
        lines.append("")
        if have_pranges:
            lines.append("| range | offset-sorted | submission-order |")
            lines.append("|---|---:|---:|")
            by_range = {}
            for r in pranges:
                by_range.setdefault(r.get("range", "?"), {})[r.get("order")] = r.get("MBps")
            for rng, cells in by_range.items():
                lines.append(
                    f"| {rng} | "
                    + " | ".join(fmt(cells.get(o)) for o in ("offset", "submission"))
                    + " |"
                )
        else:
            lines.append("*(projection_range lanes present but unfilled)*")
    concs = doc.get("concurrent") or []
    have_concs = [r for r in concs if isinstance(r.get("MBps"), (int, float))]
    if concs:
        lines.append("")
        lines.append("Concurrent scan server (waves of identical all-branch queries; "
                     "aggregate uncompressed MB/s over the wave, per-query p99 latency; "
                     "cold = fresh decoded-basket cache, warm = identical repeat wave):")
        lines.append("")
        if have_concs:
            lines.append("| queries | cold MB/s | cold p99 ms | warm MB/s | warm p99 ms |")
            lines.append("|---|---:|---:|---:|---:|")
            by_queries = {}
            for r in concs:
                by_queries.setdefault(r.get("queries", "?"), {})[r.get("cache")] = (
                    r.get("MBps"), r.get("p99_ms"))
            for queries, cells in by_queries.items():
                cold = cells.get("cold", (None, None))
                warm = cells.get("warm", (None, None))
                lines.append(
                    f"| {queries} | {fmt(cold[0])} | {fmt(cold[1])} | "
                    f"{fmt(warm[0])} | {fmt(warm[1])} |"
                )
        else:
            lines.append("*(concurrent lanes present but unfilled)*")
    repacks = doc.get("repack") or []
    have_repacks = [r for r in repacks if isinstance(r.get("read_MBps"), (int, float))]
    if repacks:
        lines.append("")
        lines.append("Profile-driven repack (zlib-6 production-style source rewritten "
                     "under a recorded analysis profile; full-tree and hot-subset "
                     "read throughput at 2 workers):")
        lines.append("")
        if have_repacks:
            lines.append("| lane | file KB | full read MB/s | hot read MB/s |")
            lines.append("|---|---:|---:|---:|")
            for r in repacks:
                fb = r.get("file_bytes")
                fb_s = f"{fb / 1024:.1f}" if isinstance(fb, (int, float)) else "—"
                lines.append(
                    f"| {r.get('lane','?')} | {fb_s} | "
                    f"{fmt(r.get('read_MBps'))} | {fmt(r.get('hot_MBps'))} |"
                )
        else:
            lines.append("*(repack lanes present but unfilled)*")
    ios = doc.get("io_backends") or []
    have_ios = [r for r in ios if isinstance(r.get("MBps"), (int, float))]
    if ios:
        lines.append("")
        lines.append("I/O backends (physical reads + uncompressed MB/s for one "
                     "full-tree sweep; remote-sim lanes add a fixed per-request "
                     "latency, hidden by prefetch depth):")
        lines.append("")
        if have_ios:
            lines.append("| backend | latency ms | depth | reads | read MB/s |")
            lines.append("|---|---:|---:|---:|---:|")
            for r in ios:
                reads = r.get("reads")
                reads_s = str(reads) if isinstance(reads, int) else "—"
                lines.append(
                    f"| {r.get('backend','?')} | {r.get('latency_ms','?')} | "
                    f"{r.get('depth','?')} | {reads_s} | {fmt(r.get('MBps'))} |"
                )
        else:
            lines.append("*(io_backends lanes present but unfilled)*")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("bench_json")
    ap.add_argument("roadmap", nargs="?", default="ROADMAP.md")
    ap.add_argument("-o", "--out", default=None,
                    help="output path (default: overwrite ROADMAP in place)")
    args = ap.parse_args()

    doc = validate(load(args.bench_json), args.bench_json)
    with open(args.roadmap) as f:
        text = f.read()
    if BEGIN not in text or END not in text:
        print(f"roadmap_fill: markers {BEGIN} / {END} not found in {args.roadmap}",
              file=sys.stderr)
        return 1
    head, rest = text.split(BEGIN, 1)
    _, tail = rest.split(END, 1)
    filled = f"{head}{BEGIN}\n{render(doc)}\n{END}{tail}"
    out = args.out or args.roadmap
    with open(out, "w") as f:
        f.write(filled)
    print(f"roadmap_fill: wrote {out}")
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except SchemaError as e:
        print(f"roadmap_fill: SCHEMA MISMATCH: {e}", file=sys.stderr)
        sys.exit(2)
