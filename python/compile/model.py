"""L2 JAX model: the basket analyzer.

The paper's §3 calls for I/O API improvements "to ease the switch between
compression algorithms and settings for different use cases"; our adaptive
planner is that feature. The heavy array math — byte histograms, entropies
of four candidate views (raw / Shuffle / BitShuffle / Delta), and run
proxies — is expressed here as one jitted function, calls the L1 Pallas
BitShuffle kernel so it lowers into the same HLO module, and is AOT-compiled
once by aot.py. The rust coordinator executes the artifact via PJRT and
applies cheap threshold logic to the returned feature vector; Python never
runs at request time.

Input : int32[(n,)]  byte values 0..255 of a basket prefix (fixed n per
                     bucket; rust truncates/samples the basket to fit).
Output: f32[(NUM_FEATURES,)] — see FEATURES.
"""

import jax.numpy as jnp

from .kernels.bitshuffle import bitshuffle
from .kernels.ref import byte_entropy_ref, repeat_fraction_ref

#: Preconditioner stride the analyzer evaluates (the dominant element size
#: in ROOT baskets: f32/i32 are both 4 bytes).
STRIDE = 4

FEATURES = (
    "H_raw",          # entropy of raw bytes
    "H_shuffle",      # entropy after byte-Shuffle(stride)
    "H_bitshuffle",   # entropy after BitShuffle(stride)
    "H_delta",        # entropy after Delta(stride)
    "rep_raw",        # adjacent-equal fraction, raw
    "rep_bitshuffle", # adjacent-equal fraction, bitshuffled
    "zero_bitshuffle",# fraction of 0x00/0xFF plane bytes after BitShuffle
    "rep_shuffle",    # adjacent-equal fraction after byte-Shuffle
)
NUM_FEATURES = len(FEATURES)


def analyze(buf):
    """Feature extraction over one basket prefix. buf: int32[(n,)], n % (8*STRIDE) == 0."""
    n = buf.shape[0]
    assert n % (8 * STRIDE) == 0, "bucket sizes are multiples of 8*stride"
    x = buf.reshape(n // STRIDE, STRIDE)

    # Candidate views.
    shuf = jnp.transpose(x, (1, 0)).reshape(-1)
    planes = bitshuffle(x).reshape(-1)  # L1 Pallas kernel
    prev = jnp.concatenate([buf[:STRIDE], buf[:-STRIDE]])
    delta = jnp.bitwise_and(buf - prev, 255)

    h_raw = byte_entropy_ref(buf)
    h_shuf = byte_entropy_ref(shuf)
    h_bits = byte_entropy_ref(planes)
    h_delta = byte_entropy_ref(delta)
    rep_raw = repeat_fraction_ref(buf)
    rep_bits = repeat_fraction_ref(planes)
    zero_bits = jnp.mean(
        jnp.logical_or(planes == 0, planes == 255).astype(jnp.float32)
    )
    rep_shuf = repeat_fraction_ref(shuf)

    return (
        jnp.stack(
            [h_raw, h_shuf, h_bits, h_delta, rep_raw, rep_bits, zero_bits, rep_shuf]
        ).astype(jnp.float32),
    )
