"""Pure-jnp reference oracles for the Pallas kernels and the analyzer.

This is the CORE correctness signal for the Python layer: the Pallas
kernel(s) in bitshuffle.py must agree with these references bit-for-bit, and
the rust `precond::bitshuffle` implements the same layout contract (the
cross-language golden test in python/tests/test_kernel.py pins it).

Layout contract (shared with rust/src/precond/bitshuffle.rs):
  * input: `nelem` elements of `stride` bytes, nelem % 8 == 0;
  * bit index within an element: byte*8 + bit, bit 0 = LSB of byte 0;
  * output plane k holds bit k of every element, packed LSB-first
    (element 8i+j -> bit j of plane byte i), planes concatenated in order.
"""

import jax.numpy as jnp
import numpy as np


def bitshuffle_ref(x):
    """Bit-transpose. x: int32[(nelem, stride)] with byte values 0..255.

    Returns int32[(stride * 8, nelem // 8)] of packed plane bytes.
    """
    nelem, stride = x.shape
    assert nelem % 8 == 0, "reference requires a multiple of 8 elements"
    # bits[e, b, i] = bit i of byte b of element e
    bits = (x[:, :, None] >> jnp.arange(8, dtype=x.dtype)[None, None, :]) & 1
    # plane index k = b*8 + i  ->  reorder to [b, i, e] then flatten planes
    planes = jnp.transpose(bits, (1, 2, 0)).reshape(stride * 8, nelem)
    # pack: element 8i+j -> bit j of output byte i (LSB-first)
    grouped = planes.reshape(stride * 8, nelem // 8, 8)
    weights = (1 << jnp.arange(8, dtype=x.dtype))[None, None, :]
    return jnp.sum(grouped * weights, axis=-1, dtype=x.dtype)


def shuffle_ref(x):
    """Byte shuffle (Blosc Shuffle). x: int32[(nelem, stride)].

    Returns int32[(stride, nelem)] — byte k of every element contiguous.
    """
    return jnp.transpose(x, (1, 0))


def byte_entropy_ref(buf):
    """Shannon entropy (bits/byte) of int32 byte values 0..255."""
    hist = jnp.zeros(256, dtype=jnp.float32).at[buf].add(1.0)
    p = hist / jnp.maximum(buf.shape[0], 1)
    logp = jnp.where(p > 0, jnp.log2(jnp.where(p > 0, p, 1.0)), 0.0)
    return -jnp.sum(p * logp)


def repeat_fraction_ref(buf):
    """Fraction of adjacent equal byte pairs."""
    if buf.shape[0] < 2:
        return jnp.float32(0.0)
    return jnp.mean((buf[1:] == buf[:-1]).astype(jnp.float32))


def bitshuffle_numpy(data: bytes, stride: int) -> bytes:
    """Byte-level mirror of rust precond::bitshuffle (incl. tail rules).

    Used by the cross-language golden test.
    """
    arr = np.frombuffer(data, dtype=np.uint8)
    n = arr.shape[0]
    if stride == 0 or n < stride * 8:
        return arr.tobytes()
    nelem_total = n // stride
    nelem = nelem_total & ~7
    body = nelem * stride
    x = arr[:body].reshape(nelem, stride).astype(np.int32)
    planes = np.asarray(bitshuffle_ref(jnp.asarray(x)))
    out = np.concatenate([planes.astype(np.uint8).reshape(-1), arr[body:]])
    return out.tobytes()
