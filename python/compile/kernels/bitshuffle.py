"""L1 Pallas kernel: BitShuffle — the paper's Fig-6 preconditioner as a
TPU-shaped tile kernel.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper is CPU-only,
so the mapping exercise is expressing the bit-matrix transpose for a vector
unit. On TPU the natural shape is: the grid walks element-tiles of the
basket; each step loads a `[TILE_ELEMS, stride]` byte tile into VMEM
(BlockSpec below), unpacks to bit planes with lane-wise shifts (VPU work —
no MXU involvement), packs LSB-first, and writes the `[stride*8, TILE_ELEMS/8]`
plane tile back. VMEM estimate for the default 32 KiB basket at stride 4:
8192×4 int32 in + 8×8192 bit expansion ≈ 1.3 MiB, comfortably inside the
~16 MiB VMEM budget; larger baskets raise the grid count, not the tile.

MUST run interpret=True here: real-TPU lowering emits a Mosaic custom-call
the CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Elements per grid step. 1024 elements × stride bytes per tile.
TILE_ELEMS = 1024


def _bitshuffle_kernel(x_ref, o_ref):
    """One tile: x_ref int32[(TILE, stride)] -> o_ref int32[(stride*8, TILE//8)]."""
    x = x_ref[...]
    tile, stride = x.shape
    bits = (x[:, :, None] >> jnp.arange(8, dtype=x.dtype)[None, None, :]) & 1
    planes = jnp.transpose(bits, (1, 2, 0)).reshape(stride * 8, tile)
    grouped = planes.reshape(stride * 8, tile // 8, 8)
    weights = (1 << jnp.arange(8, dtype=x.dtype))[None, None, :]
    o_ref[...] = jnp.sum(grouped * weights, axis=-1, dtype=x.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitshuffle(x, interpret=True):
    """BitShuffle via pallas_call. x: int32[(nelem, stride)], nelem % 8 == 0.

    Returns int32[(stride*8, nelem//8)]. For nelem <= TILE_ELEMS a single
    tile; otherwise the grid walks element blocks (nelem must then be a
    multiple of TILE_ELEMS — the AOT wrapper pads basket buckets to this).
    """
    nelem, stride = x.shape
    if nelem % 8 != 0:
        raise ValueError("nelem must be a multiple of 8")
    if nelem <= TILE_ELEMS:
        return pl.pallas_call(
            _bitshuffle_kernel,
            out_shape=jax.ShapeDtypeStruct((stride * 8, nelem // 8), x.dtype),
            interpret=interpret,
        )(x)
    if nelem % TILE_ELEMS != 0:
        raise ValueError("nelem must be a multiple of TILE_ELEMS for gridding")
    grid = nelem // TILE_ELEMS
    return pl.pallas_call(
        _bitshuffle_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((TILE_ELEMS, stride), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((stride * 8, TILE_ELEMS // 8), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((stride * 8, nelem // 8), x.dtype),
        interpret=interpret,
    )(x)


def _shuffle_kernel(x_ref, o_ref):
    """Byte Shuffle tile kernel: transpose [TILE, stride] -> [stride, TILE]."""
    o_ref[...] = jnp.transpose(x_ref[...], (1, 0))


@functools.partial(jax.jit, static_argnames=("interpret",))
def shuffle(x, interpret=True):
    """Blosc byte-Shuffle via pallas_call. x: int32[(nelem, stride)]."""
    nelem, stride = x.shape
    if nelem <= TILE_ELEMS:
        return pl.pallas_call(
            _shuffle_kernel,
            out_shape=jax.ShapeDtypeStruct((stride, nelem), x.dtype),
            interpret=interpret,
        )(x)
    if nelem % TILE_ELEMS != 0:
        raise ValueError("nelem must be a multiple of TILE_ELEMS for gridding")
    grid = nelem // TILE_ELEMS
    return pl.pallas_call(
        _shuffle_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((TILE_ELEMS, stride), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((stride, TILE_ELEMS), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((stride, nelem), x.dtype),
        interpret=interpret,
    )(x)
