"""AOT lowering: jit(analyze) -> HLO *text* artifacts for the rust runtime.

HLO text (not `.serialize()` / serialized HloModuleProto) is the interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids that the
xla_extension 0.5.1 bundled with the published `xla` crate rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/gen_hlo.py and its README.

One artifact per basket-size bucket so shapes stay static (no recompiles on
the request path). Buckets are multiples of 8*STRIDE and of the Pallas
TILE_ELEMS*STRIDE so the gridded kernel tiles exactly.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import analyze, NUM_FEATURES, STRIDE

#: Basket-prefix sizes (bytes) we compile analyzers for. Rust picks the
#: largest bucket <= basket size (and skips analysis below the smallest).
BUCKETS = (4096, 32768, 262144)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(n: int) -> str:
    spec = jax.ShapeDtypeStruct((n,), jnp.int32)
    lowered = jax.jit(analyze).lower(spec)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-artifact path (Makefile stamp)")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    for n in BUCKETS:
        assert n % (8 * STRIDE) == 0
        text = lower_bucket(n)
        path = out_dir / f"analyzer_{n}.hlo.txt"
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars, input int32[{n}], output f32[{NUM_FEATURES}])")

    # Stamp file used by the Makefile to detect staleness.
    stamp = pathlib.Path(args.out) if args.out else out_dir / "model.hlo.txt"
    stamp.write_text(
        "\n".join(f"analyzer_{n}.hlo.txt" for n in BUCKETS) + "\n"
    )
    print(f"wrote {stamp} (artifact manifest)")


if __name__ == "__main__":
    main()
