"""L1 correctness: Pallas kernels vs the pure-jnp reference, swept with
hypothesis over shapes/strides/contents, plus layout-contract goldens that
pin the cross-language agreement with rust/src/precond/bitshuffle.rs."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.bitshuffle import bitshuffle, shuffle, TILE_ELEMS
from compile.kernels.ref import bitshuffle_ref, bitshuffle_numpy, shuffle_ref


def _rand_bytes(rng, nelem, stride):
    return rng.integers(0, 256, size=(nelem, stride), dtype=np.int32)


@settings(max_examples=40, deadline=None)
@given(
    nelem8=st.integers(min_value=1, max_value=96),
    stride=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_bitshuffle_matches_ref_single_tile(nelem8, stride, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(_rand_bytes(rng, 8 * nelem8, stride))
    got = np.asarray(bitshuffle(x))
    want = np.asarray(bitshuffle_ref(x))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(
    tiles=st.integers(min_value=2, max_value=4),
    stride=st.sampled_from([1, 4]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_bitshuffle_gridded_matches_ref(tiles, stride, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(_rand_bytes(rng, tiles * TILE_ELEMS, stride))
    got = np.asarray(bitshuffle(x))
    want = np.asarray(bitshuffle_ref(x))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(
    nelem=st.integers(min_value=8, max_value=512),
    stride=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_shuffle_matches_ref(nelem, stride, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(_rand_bytes(rng, nelem, stride))
    got = np.asarray(shuffle(x))
    want = np.asarray(shuffle_ref(x))
    np.testing.assert_array_equal(got, want)


def test_layout_contract_single_bit():
    """Same golden as rust's `single_bit_lands_in_right_plane` test:
    8 elements x 2 bytes, element 3 has bit 5 of byte 1 set ->
    plane 13, byte 0, bit 3."""
    x = np.zeros((8, 2), dtype=np.int32)
    x[3, 1] = 1 << 5
    got = np.asarray(bitshuffle(jnp.asarray(x)))
    assert got.shape == (16, 1)
    for plane in range(16):
        expect = (1 << 3) if plane == 13 else 0
        assert got[plane, 0] == expect, f"plane {plane}"


def test_monotone_offsets_mostly_zero():
    """Fig-6 mechanism: BE-serialized offsets 1..512 leave only low bit
    planes non-constant (mirrors the rust test)."""
    offs = np.arange(1, 513, dtype=">u4").tobytes()
    x = np.frombuffer(offs, dtype=np.uint8).reshape(512, 4).astype(np.int32)
    got = np.asarray(bitshuffle(jnp.asarray(x)))
    zeros = int((got == 0).sum())
    assert zeros > 0.6 * got.size, f"zeros={zeros}/{got.size}"


@settings(max_examples=20, deadline=None)
@given(
    nbytes=st.integers(min_value=0, max_value=2000),
    stride=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_numpy_mirror_is_self_consistent(nbytes, stride, seed):
    """bitshuffle_numpy (the byte-level mirror incl. tail handling) must be
    a permutation-with-tail of the input: same multiset of bytes in body,
    identical tail bytes."""
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 256, size=nbytes, dtype=np.uint8).tobytes()
    out = bitshuffle_numpy(data, stride)
    assert len(out) == len(data)
    if stride > 0 and nbytes >= stride * 8:
        nelem = (nbytes // stride) & ~7
        body = nelem * stride
        assert out[body:] == data[body:]


def test_interpret_flag_required_for_cpu():
    """Document the constraint: interpret=False would lower to a Mosaic
    custom-call; on CPU we always pass interpret=True (default)."""
    x = jnp.zeros((8, 4), dtype=jnp.int32)
    out = bitshuffle(x)  # default interpret=True must work on CPU
    assert out.shape == (32, 1)
