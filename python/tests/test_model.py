"""L2 correctness: analyzer features vs a plain-numpy reference, and the
AOT artifacts' shape contract."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.model import analyze, NUM_FEATURES, STRIDE
from compile.aot import BUCKETS, lower_bucket


def entropy_np(b):
    hist = np.bincount(b, minlength=256).astype(np.float64)
    p = hist / max(len(b), 1)
    nz = p[p > 0]
    return float(-(nz * np.log2(nz)).sum())


def features_np(buf):
    n = len(buf)
    x = buf.reshape(n // STRIDE, STRIDE)
    shuf = x.T.reshape(-1)
    # BitShuffle via the reference mirror.
    from compile.kernels.ref import bitshuffle_numpy

    planes = np.frombuffer(
        bitshuffle_numpy(buf.astype(np.uint8).tobytes(), STRIDE), dtype=np.uint8
    ).astype(np.int64)
    prev = np.concatenate([buf[:STRIDE], buf[:-STRIDE]])
    delta = (buf - prev) & 255
    rep = lambda a: float((a[1:] == a[:-1]).mean()) if len(a) > 1 else 0.0
    return np.array(
        [
            entropy_np(buf),
            entropy_np(shuf),
            entropy_np(planes),
            entropy_np(delta),
            rep(buf),
            rep(planes),
            float(((planes == 0) | (planes == 255)).mean()),
            rep(shuf),
        ],
        dtype=np.float32,
    )


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31), kind=st.sampled_from(["noise", "offsets", "runs"]))
def test_analyze_matches_numpy(seed, kind):
    rng = np.random.default_rng(seed)
    n = 4096
    if kind == "noise":
        buf = rng.integers(0, 256, size=n, dtype=np.int64)
    elif kind == "offsets":
        offs = np.arange(1, n // 4 + 1, dtype=">u4").tobytes()
        buf = np.frombuffer(offs, dtype=np.uint8).astype(np.int64)
    else:
        buf = np.repeat(rng.integers(0, 256, size=n // 64, dtype=np.int64), 64)
    (got,) = analyze(jnp.asarray(buf, dtype=jnp.int32))
    want = features_np(buf)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_feature_separation_on_canonical_inputs():
    """The planner's signal: offset arrays must show a big entropy drop
    under BitShuffle; noise must not."""
    n = 4096
    offs = np.arange(1, n // 4 + 1, dtype=">u4").tobytes()
    buf_off = np.frombuffer(offs, dtype=np.uint8).astype(np.int64)
    (f_off,) = analyze(jnp.asarray(buf_off, dtype=jnp.int32))
    f_off = np.asarray(f_off)
    assert f_off[2] < 0.5 * f_off[0], f"bitshuffle entropy {f_off[2]} vs raw {f_off[0]}"

    rng = np.random.default_rng(0)
    buf_noise = rng.integers(0, 256, size=n, dtype=np.int64)
    (f_noise,) = analyze(jnp.asarray(buf_noise, dtype=jnp.int32))
    f_noise = np.asarray(f_noise)
    assert f_noise[2] > 0.95 * f_noise[0]


@pytest.mark.parametrize("n", BUCKETS)
def test_buckets_lower_to_hlo(n):
    text = lower_bucket(n)
    assert "HloModule" in text
    # Output tuple of one f32[NUM_FEATURES] array.
    assert f"f32[{NUM_FEATURES}]" in text


def test_bucket_sizes_are_tileable():
    from compile.kernels.bitshuffle import TILE_ELEMS

    for n in BUCKETS:
        nelem = n // STRIDE
        assert n % (8 * STRIDE) == 0
        assert nelem <= TILE_ELEMS or nelem % TILE_ELEMS == 0
