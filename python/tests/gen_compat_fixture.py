#!/usr/bin/env python3
"""Generate rust/tests/fixtures/compat_v2.rfile: a tiny RFIL **v2** file
whose baskets carry dual-state (mode 2) FSE literal sections.

The v3 reader must keep decoding v2 files event-for-event identical
(docs/FORMAT.md section 9), so the conformance suite pins a committed v2
file produced by this script. The byte layout is built here from scratch —
an independent transliteration of the Rust dual-state FSE encoder
(rust/src/zstd/fse.rs), the RZS1 container (rust/src/zstd/compress.rs,
with n_seq = 0: a pure-literals block is a layout any v2 writer can emit),
the 10-byte span header (rust/src/compression/record.rs) and the RFIL
record/metadata framing (rust/src/rfile/{format,basket,writer,meta}.rs) —
so the fixture cannot inherit a bug from the code it is meant to check.

The script decodes its own output with a forward FSE decoder and a full
file parse before writing anything, then emits the fixture plus the
expected events (mirrored by `expected_fixture_events()` in
rust/tests/conformance_entropy.rs).

Run from the repo root:  python3 python/tests/gen_compat_fixture.py
"""

import struct
import sys
from pathlib import Path

OUT_PATH = Path(__file__).resolve().parents[2] / "rust" / "tests" / "fixtures" / "compat_v2.rfile"

# --- varint / record helpers (rust/src/util/varint.rs, rfile/format.rs) ---

def uvarint(v: int) -> bytes:
    out = bytearray()
    while True:
        b = v & 0x7F
        v >>= 7
        if v == 0:
            out.append(b)
            return bytes(out)
        out.append(b | 0x80)


def lp(data: bytes) -> bytes:
    return uvarint(len(data)) + data


def record(kind: int, payload: bytes) -> bytes:
    return struct.pack(">I", len(payload) + 5) + bytes([kind]) + payload


def span_header(tag: bytes, level: int, comp_len: int, uncomp_len: int) -> bytes:
    """10-byte span header: tag, level nibble, u24le sizes, precond byte."""
    h = bytearray(tag)
    h.append(level & 0x0F)
    h += comp_len.to_bytes(3, "little")
    h += uncomp_len.to_bytes(3, "little")
    h.append(0)  # Precond::None
    return bytes(h)


# --- FSE transliteration (rust/src/zstd/fse.rs) -------------------------

def optimal_table_log(total: int, present: int, max_log: int) -> int:
    if total > 1:
        log = max((total - 1).bit_length() - 1 - 2, 0)
    else:
        log = 5
    min_for_alphabet = (max(present, 2) - 1).bit_length() + 1
    return min(max(log, min_for_alphabet, 5), max_log)


def normalize_counts(hist, total: int, table_log: int):
    size = 1 << table_log
    present = sum(1 for c in hist if c > 0)
    assert 0 < present <= size and total > 0
    norm = [0] * len(hist)
    if present == 1:
        norm[next(i for i, c in enumerate(hist) if c > 0)] = size
        return norm
    assigned = 0
    for s, c in enumerate(hist):
        if c == 0:
            continue
        scaled = (c * size) // total
        v = min(max(scaled, 1), size - 1)
        norm[s] = v
        assigned += v
    rest = size - assigned
    while rest > 0:
        # Rust max_by_key keeps the *last* maximum on ties.
        best_s, best_key = 0, None
        for s in range(len(hist)):
            key = (norm[s], hist[s])
            if best_key is None or key >= best_key:
                best_key, best_s = key, s
        add = max(min(rest, size // 8), 1)
        norm[best_s] += add
        rest -= add
    while rest < 0:
        # Strictly-greater comparison keeps the *first* maximum on ties.
        best = None
        for s in range(len(hist)):
            if norm[s] > 1:
                ratio = norm[s] * total / (max(hist[s], 1) * size)
                if best is None or ratio > best[0]:
                    best = (ratio, s)
        assert best is not None, "normalization failed"
        norm[best[1]] -= 1
        rest += 1
    assert sum(norm) == size
    return norm


def spread_symbols(norm, table_log: int):
    size = 1 << table_log
    table = [0] * size
    step = (size >> 1) + (size >> 3) + 3
    mask = size - 1
    pos = 0
    for sym, count in enumerate(norm):
        for _ in range(count):
            table[pos] = sym
            pos = (pos + step) & mask
    assert pos == 0
    return table


class EncTable:
    def __init__(self, norm, table_log: int):
        size = 1 << table_log
        spread = spread_symbols(norm, table_log)
        cumul = [0] * (len(norm) + 1)
        for s in range(len(norm)):
            cumul[s + 1] = cumul[s] + norm[s]
        self.table_log = table_log
        self.next_state = [0] * size
        cursor = list(cumul)
        for p, sym in enumerate(spread):
            self.next_state[cursor[sym]] = size + p
            cursor[sym] += 1
        self.sym = [(0, 0)] * len(norm)
        self.seed = [0] * len(norm)
        total = 0
        for s, count in enumerate(norm):
            if count == 0:
                continue
            self.seed[s] = self.next_state[total]
            if count == 1:
                self.sym[s] = (total - 1, ((table_log << 16) - (1 << table_log)) & 0xFFFFFFFF)
            else:
                max_bits = table_log - ((count - 1).bit_length() - 1)
                self.sym[s] = (total - count, ((max_bits << 16) - (count << max_bits)) & 0xFFFFFFFF)
            total += count


class BitWriter:
    """LSB-first, matching rust/src/util/bitio.rs byte-for-byte."""

    def __init__(self):
        self.out = bytearray()
        self.acc = 0
        self.nbits = 0

    def write_bits(self, bits: int, n: int):
        self.acc |= bits << self.nbits
        self.nbits += n
        while self.nbits >= 8:
            self.out.append(self.acc & 0xFF)
            self.acc >>= 8
            self.nbits -= 8

    def finish(self) -> bytes:
        if self.nbits > 0:
            self.out.append(self.acc & 0xFF)
            self.acc = 0
            self.nbits = 0
        return bytes(self.out)


def encode_interleaved(enc: EncTable, symbols) -> tuple:
    """Dual-state encode: the v2 stream layout (even lanes 0, odd lane 1)."""
    size = 1 << enc.table_log
    states = [size, size]
    seeded = [False, False]
    chunks = []
    for i in reversed(range(len(symbols))):
        s = symbols[i]
        lane = i & 1
        if not seeded[lane]:
            states[lane] = enc.seed[s]
            seeded[lane] = True
            continue
        delta_find, delta_nb = enc.sym[s]
        st = states[lane]
        nb = ((delta_nb + st) & 0xFFFFFFFF) >> 16
        chunks.append((st & ((1 << nb) - 1), nb))
        states[lane] = enc.next_state[(st >> nb) + delta_find]
    w = BitWriter()
    for bits, nb in reversed(chunks):
        w.write_bits(bits, nb)
    return w.finish(), (states[0], states[1])


def write_norm(norm, table_log: int) -> bytes:
    out = bytearray([table_log])
    last = 0
    for i, c in enumerate(norm):
        if c > 0:
            last = i + 1
    out += uvarint(last)
    zeros = 0
    for c in norm[:last]:
        if c == 0:
            zeros += 1
            continue
        if zeros > 0:
            out += uvarint(0) + uvarint(zeros)
            zeros = 0
        out += uvarint(c)
    return bytes(out)


# --- forward decoder (self-verification only) ---------------------------

def dec_entries(norm, table_log: int):
    size = 1 << table_log
    occ = [0] * len(norm)
    entries = []
    for sym in spread_symbols(norm, table_log):
        x = norm[sym] + occ[sym]
        occ[sym] += 1
        nb = table_log - (x.bit_length() - 1)
        entries.append((sym, nb, (x << nb) - size))
    return entries


class BitReaderFwd:
    def __init__(self, data: bytes):
        self.data = data
        self.pos = 0
        self.over = False

    def read_bits(self, n: int) -> int:
        v = 0
        for j in range(n):
            byte_i = (self.pos + j) >> 3
            if byte_i < len(self.data):
                v |= ((self.data[byte_i] >> ((self.pos + j) & 7)) & 1) << j
            else:
                self.over = True
        self.pos += n
        return v


def decode_interleaved(norm, table_log: int, init, count: int, payload: bytes):
    size = 1 << table_log
    entries = dec_entries(norm, table_log)
    states = [s - size for s in init]
    assert all(0 <= s < size for s in states), "invalid initial state"
    r = BitReaderFwd(payload)
    out = []
    for k in range(count):
        lane = k & 1
        sym, nb, base = entries[states[lane]]
        out.append(sym)
        if k + 2 < count:
            states[lane] = base + r.read_bits(nb)
    assert not r.over, "payload exhausted"
    return out


# --- RZS1 v2 block + basket assembly ------------------------------------

def fse_literal_section(data: bytes) -> bytes:
    """Mode-2 (dual-state) literal section, exactly what a v2 writer emits:
    [mode=2][len][norm table][state0][state1][payload_len][payload]."""
    hist = [0] * 256
    for b in data:
        hist[b] += 1
    present = sum(1 for c in hist if c > 0)
    assert present >= 2 and len(data) >= 32, "data would pick raw/rle mode"
    log = optimal_table_log(len(data), present, 11)
    norm = normalize_counts(hist, len(data), log)
    enc = EncTable(norm, log)
    payload, states = encode_interleaved(enc, data)
    # Self-check: forward decode recovers the input.
    assert bytes(decode_interleaved(norm, log, states, len(data), payload)) == data
    section = write_norm(norm, log) + uvarint(states[0]) + uvarint(states[1])
    section += uvarint(len(payload)) + payload
    # The v2 encoder only picks FSE when it wins; keep the fixture honest.
    assert len(section) + 2 < len(data), "FSE section failed to win; pick skewer data"
    return bytes([2]) + uvarint(len(data)) + section


def rzs1_block(logical: bytes) -> bytes:
    """Pure-literals RZS1 block: [raw_len][n_seq=0][literal section]."""
    return uvarint(len(logical)) + uvarint(0) + fse_literal_section(logical)


def basket_record_payload(branch_id: int, basket_index: int, n_entries: int,
                          data: bytes, offsets) -> bytes:
    logical = data + b"".join(struct.pack(">I", o) for o in offsets)
    blob = rzs1_block(logical)
    assert len(blob) < len(logical), "span would be stored raw, not ZS"
    payload = uvarint(branch_id) + uvarint(basket_index)
    payload += uvarint(n_entries) + uvarint(len(data)) + uvarint(len(offsets))
    payload += span_header(b"ZS", 5, len(blob), len(logical)) + blob
    return payload, len(logical)


# --- fixture content (mirrored in rust/tests/conformance_entropy.rs) ----

N_ENTRIES = 37
TAG_NAMES = [b"Muon_pt", b"Jet_eta", b"MET_phi", b"Tau_q", b"HLT_Iso"]


def expected_events():
    events = []
    for i in range(N_ENTRIES):
        if i % 7 == 3:
            tag = b""
        else:
            tag = TAG_NAMES[i % 5] + bytes([ord("0") + i % 10])
        events.append((tag, i * 0.5 - 3.0))
    return events


def build_file() -> bytes:
    events = expected_events()
    # Branch 0 "tag" (VarU8, type code 7): jagged bytes + offset array.
    tag_data = bytearray()
    tag_offsets = []
    for tag, _ in events:
        tag_data += tag
        tag_offsets.append(len(tag_data))
    # Branch 1 "e" (F32, type code 0): fixed-width big-endian floats.
    e_data = b"".join(struct.pack(">f", v) for _, v in events)

    p0, logical0 = basket_record_payload(0, 0, N_ENTRIES, bytes(tag_data), tag_offsets)
    p1, logical1 = basket_record_payload(1, 0, N_ENTRIES, e_data, [])

    out = bytearray(b"RFIL" + (2).to_bytes(2, "big"))  # v2 header
    off0 = len(out)
    out += record(1, p0)
    off1 = len(out)
    out += record(1, p1)
    meta_off = len(out)

    # TreeMeta (rust/src/rfile/meta.rs::serialize).
    meta = bytearray()
    meta += lp(b"Events")
    meta += uvarint(2)
    meta += lp(b"tag") + bytes([7, 0])  # VarU8, no per-branch settings
    meta += lp(b"e") + bytes([0, 0])    # F32,   no per-branch settings
    meta += uvarint(505)                # default settings: ZSTD-5
    meta.append(0)                      # precond byte: None
    meta += uvarint(N_ENTRIES)
    meta.append(0)                      # no dictionary
    meta += uvarint(2)                  # two baskets
    for branch_id, off, payload, logical in [(0, off0, p0, logical0), (1, off1, p1, logical1)]:
        meta += uvarint(branch_id) + uvarint(0) + uvarint(0) + uvarint(N_ENTRIES)
        meta += uvarint(off) + uvarint(len(payload)) + uvarint(logical)
    out += record(2, bytes(meta))
    out += struct.pack(">Q", meta_off) + b"RFILEND1"
    return bytes(out)


# --- independent re-parse of the finished file --------------------------

class Cursor:
    def __init__(self, data: bytes):
        self.data = data
        self.pos = 0

    def uvarint(self) -> int:
        v, shift = 0, 0
        while True:
            b = self.data[self.pos]
            self.pos += 1
            v |= (b & 0x7F) << shift
            if b & 0x80 == 0:
                return v
            shift += 7

    def u8(self) -> int:
        b = self.data[self.pos]
        self.pos += 1
        return b

    def take(self, n: int) -> bytes:
        b = self.data[self.pos:self.pos + n]
        assert len(b) == n, "truncated"
        self.pos += n
        return b


def parse_basket(payload: bytes):
    c = Cursor(payload)
    branch_id, basket_index = c.uvarint(), c.uvarint()
    n_entries, data_len, n_offsets = c.uvarint(), c.uvarint(), c.uvarint()
    hdr = c.take(10)
    assert hdr[:2] == b"ZS" and hdr[9] == 0
    comp_len = int.from_bytes(hdr[3:6], "little")
    uncomp_len = int.from_bytes(hdr[6:9], "little")
    blob = c.take(comp_len)
    assert c.pos == len(payload), "trailing bytes after span"
    # RZS1: raw_len, n_seq = 0, mode-2 literal section.
    b = Cursor(blob)
    raw_len = b.uvarint()
    assert raw_len == uncomp_len and b.uvarint() == 0
    assert b.u8() == 2, "fixture must use the dual-state (mode 2) section"
    lit_len = b.uvarint()
    assert lit_len == raw_len
    table_log = b.u8()
    n = b.uvarint()
    norm, i = [0] * n, 0
    while i < n:
        v = b.uvarint()
        if v == 0:
            i += b.uvarint()
        else:
            norm[i] = v
            i += 1
    assert sum(norm) == 1 << table_log
    states = (b.uvarint(), b.uvarint())
    fse_payload = b.take(b.uvarint())
    assert b.pos == len(blob), "trailing bytes after FSE payload"
    logical = bytes(decode_interleaved(norm, table_log, states, lit_len, fse_payload))
    assert len(logical) == data_len + 4 * n_offsets
    data, off_bytes = logical[:data_len], logical[data_len:]
    offsets = [int.from_bytes(off_bytes[j:j + 4], "big") for j in range(0, len(off_bytes), 4)]
    return branch_id, basket_index, n_entries, data, offsets


def verify(blob: bytes):
    assert blob[:4] == b"RFIL" and blob[4:6] == b"\x00\x02", "must be a v2 container"
    assert blob[-8:] == b"RFILEND1"
    meta_off = struct.unpack(">Q", blob[-16:-8])[0]

    def rec_at(off: int):
        total = struct.unpack(">I", blob[off:off + 4])[0]
        return blob[off + 4], blob[off + 5:off + total]

    kind, meta = rec_at(meta_off)
    assert kind == 2
    c = Cursor(meta)
    assert c.take(c.uvarint()) == b"Events"
    n_branches = c.uvarint()
    branches = []
    for _ in range(n_branches):
        name = c.take(c.uvarint())
        ty, has = c.u8(), c.u8()
        assert has == 0
        branches.append((name, ty))
    assert branches == [(b"tag", 7), (b"e", 0)]
    assert c.uvarint() == 505 and c.u8() == 0
    assert c.uvarint() == N_ENTRIES and c.u8() == 0
    n_baskets = c.uvarint()
    assert n_baskets == 2

    events = expected_events()
    for _ in range(n_baskets):
        branch_id = c.uvarint()
        assert c.uvarint() == 0 and c.uvarint() == 0 and c.uvarint() == N_ENTRIES
        off, comp_len, uncomp_len = c.uvarint(), c.uvarint(), c.uvarint()
        kind, payload = rec_at(off)
        assert kind == 1 and len(payload) == comp_len
        bid, bidx, n_entries, data, offsets = parse_basket(payload)
        assert bid == branch_id and bidx == 0 and n_entries == N_ENTRIES
        if branch_id == 0:
            assert len(offsets) == N_ENTRIES
            start = 0
            for i, end in enumerate(offsets):
                assert data[start:end] == events[i][0], f"tag mismatch at entry {i}"
                start = end
            assert uncomp_len == len(data) + 4 * N_ENTRIES
        else:
            assert offsets == [] and uncomp_len == len(data) == 4 * N_ENTRIES
            for i in range(N_ENTRIES):
                (got,) = struct.unpack(">f", data[4 * i:4 * i + 4])
                assert got == events[i][1], f"f32 mismatch at entry {i}"
    assert c.pos == len(meta), "trailing metadata bytes"


def main():
    blob = build_file()
    verify(blob)
    OUT_PATH.parent.mkdir(parents=True, exist_ok=True)
    existing = OUT_PATH.read_bytes() if OUT_PATH.exists() else None
    if existing == blob:
        print(f"unchanged: {OUT_PATH} ({len(blob)} bytes)")
    else:
        OUT_PATH.write_bytes(blob)
        print(f"wrote {OUT_PATH} ({len(blob)} bytes)")
    if "--check" in sys.argv and existing != blob:
        print("error: committed fixture is stale", file=sys.stderr)
        sys.exit(1)
    print("compat fixture self-check OK")


if __name__ == "__main__":
    main()
