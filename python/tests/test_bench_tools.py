#!/usr/bin/env python3
"""Self-tests for the bench tooling contract CI leans on:

  * `bench_diff.py` — schema validation (v1..v8), lane-coverage checks,
    and the `--gate-fastpath` perf gate with its exit codes (0 ok, 2
    schema mismatch, 3 perf regression);
  * `roadmap_fill.py` — marker-block replacement and table rendering for
    every section of a v8 document.

These run in the CI `python` job so bench-tooling drift fails the build
even when no Rust toolchain is in play. Run:

    python3 python/tests/test_bench_tools.py
"""

import json
import os
import subprocess
import sys
import tempfile
import unittest

PYDIR = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_DIFF = os.path.join(PYDIR, "bench_diff.py")
ROADMAP_FILL = os.path.join(PYDIR, "roadmap_fill.py")

sys.path.insert(0, PYDIR)
from bench_diff import SchemaError, validate  # noqa: E402


def v3_doc(speedup=3.0, with_values=True):
    """A minimal well-formed bench-codecs/v3 document."""
    def mbps(v):
        return v if with_values else None

    return {
        "schema": "bench-codecs/v3",
        "generated_by": "test",
        "quick_mode": True,
        "corpus": "test",
        "results": [
            {
                "payload": "offsets",
                "setting": "LZ4-1",
                "codec": "LZ4",
                "level": 1,
                "precond": "none",
                "ratio": 2.0,
                "compress_MBps": mbps(100.0),
                "decompress_MBps": mbps(500.0),
            }
        ],
        "fast_path_speedups": [
            {
                "name": "lz4_decode_wildcopy_vs_naive",
                "payload": "text",
                "fast_MBps": mbps(3000.0),
                "reference_MBps": mbps(1000.0),
                "speedup": speedup if with_values else None,
            }
        ],
        "read_pipeline": [
            {"setting": "ZSTD-5", "workers": 0, "MBps": mbps(400.0)},
            {"setting": "ZSTD-5", "workers": 4, "MBps": mbps(1500.0)},
        ],
        "projection": [
            {"branches": "2of8", "order": "serial", "workers": 0, "MBps": mbps(300.0)},
            {"branches": "2of8", "order": "offset", "workers": 4, "MBps": mbps(900.0)},
            {"branches": "2of8", "order": "submission", "workers": 4, "MBps": mbps(700.0)},
        ],
    }


def v4_doc(speedup=3.0, with_values=True):
    """A minimal well-formed bench-codecs/v4 document (v3 + projection_range)."""
    def mbps(v):
        return v if with_values else None

    doc = v3_doc(speedup=speedup, with_values=with_values)
    doc["schema"] = "bench-codecs/v4"
    doc["projection_range"] = [
        {"range": "full", "order": "offset", "workers": 4, "MBps": mbps(950.0)},
        {"range": "full", "order": "submission", "workers": 4, "MBps": mbps(720.0)},
        {"range": "mid50", "order": "offset", "workers": 4, "MBps": mbps(910.0)},
        {"range": "mid50", "order": "submission", "workers": 4, "MBps": mbps(680.0)},
    ]
    return doc


def v5_doc(speedup=3.0, with_values=True):
    """A minimal well-formed bench-codecs/v5 document (v4 + concurrent)."""
    def mbps(v):
        return v if with_values else None

    doc = v4_doc(speedup=speedup, with_values=with_values)
    doc["schema"] = "bench-codecs/v5"
    doc["concurrent"] = [
        {"queries": 1, "cache": "cold", "MBps": mbps(600.0), "p99_ms": mbps(40.0)},
        {"queries": 1, "cache": "warm", "MBps": mbps(2400.0), "p99_ms": mbps(10.0)},
        {"queries": 8, "cache": "cold", "MBps": mbps(1400.0), "p99_ms": mbps(120.0)},
        {"queries": 8, "cache": "warm", "MBps": mbps(5200.0), "p99_ms": mbps(30.0)},
    ]
    return doc


def v6_doc(speedup=3.0, with_values=True):
    """A minimal well-formed bench-codecs/v6 document (v5 + entropy)."""
    def mbps(v):
        return v if with_values else None

    doc = v5_doc(speedup=speedup, with_values=with_values)
    doc["schema"] = "bench-codecs/v6"
    doc["entropy"] = [
        {"lane": "fse2", "payload": "nanoaod", "ratio": 1.6,
         "encode_MBps": mbps(300.0), "decode_MBps": mbps(450.0)},
        {"lane": "fse4", "payload": "nanoaod", "ratio": 1.6,
         "encode_MBps": mbps(420.0), "decode_MBps": mbps(700.0)},
        {"lane": "huff0", "payload": "noise", "ratio": 1.0,
         "encode_MBps": mbps(500.0), "decode_MBps": mbps(800.0)},
    ]
    return doc


def v7_doc(speedup=3.0, with_values=True):
    """A minimal well-formed bench-codecs/v7 document (v6 + repack)."""
    def mbps(v):
        return v if with_values else None

    doc = v6_doc(speedup=speedup, with_values=with_values)
    doc["schema"] = "bench-codecs/v7"
    doc["repack"] = [
        {"lane": "before", "file_bytes": mbps(4_200_000),
         "read_MBps": mbps(350.0), "hot_MBps": mbps(280.0)},
        {"lane": "after", "file_bytes": mbps(3_900_000),
         "read_MBps": mbps(900.0), "hot_MBps": mbps(1400.0)},
    ]
    return doc


def v8_doc(speedup=3.0, with_values=True):
    """A minimal well-formed bench-codecs/v8 document (v7 + io_backends)."""
    def mbps(v):
        return v if with_values else None

    doc = v7_doc(speedup=speedup, with_values=with_values)
    doc["schema"] = "bench-codecs/v8"
    doc["io_backends"] = [
        {"backend": "pread", "latency_ms": 0, "depth": 8,
         "reads": 96 if with_values else None, "MBps": mbps(800.0)},
        {"backend": "coalesced", "latency_ms": 0, "depth": 8,
         "reads": 3 if with_values else None, "MBps": mbps(950.0)},
        {"backend": "mmap", "latency_ms": 0, "depth": 8,
         "reads": 5 if with_values else None, "MBps": mbps(980.0)},
        {"backend": "remote-sim", "latency_ms": 10, "depth": 2,
         "reads": 96 if with_values else None, "MBps": mbps(12.0)},
        {"backend": "remote-sim", "latency_ms": 10, "depth": 32,
         "reads": 96 if with_values else None, "MBps": mbps(310.0)},
    ]
    return doc


def write_doc(tmp, name, doc):
    path = os.path.join(tmp, name)
    with open(path, "w") as f:
        json.dump(doc, f)
    return path


def run_diff(*argv):
    return subprocess.run(
        [sys.executable, BENCH_DIFF, *argv], capture_output=True, text=True
    )


class ValidateTests(unittest.TestCase):
    def test_v3_roundtrip(self):
        validate(v3_doc(), "doc")

    def test_unknown_schema_rejected(self):
        doc = v3_doc()
        doc["schema"] = "bench-codecs/v99"
        with self.assertRaises(SchemaError):
            validate(doc, "doc")

    def test_v3_requires_projection_section(self):
        doc = v3_doc()
        del doc["projection"]
        with self.assertRaises(SchemaError):
            validate(doc, "doc")

    def test_v2_does_not_require_projection(self):
        doc = v3_doc()
        doc["schema"] = "bench-codecs/v2"
        del doc["projection"]
        validate(doc, "doc")

    def test_projection_rows_need_keys(self):
        doc = v3_doc()
        del doc["projection"][0]["order"]
        with self.assertRaises(SchemaError):
            validate(doc, "doc")

    def test_v4_roundtrip(self):
        validate(v4_doc(), "doc")

    def test_v4_requires_projection_range_section(self):
        doc = v4_doc()
        del doc["projection_range"]
        with self.assertRaises(SchemaError):
            validate(doc, "doc")

    def test_v3_does_not_require_projection_range(self):
        validate(v3_doc(), "doc")  # no projection_range key at all

    def test_projection_range_rows_need_keys(self):
        doc = v4_doc()
        del doc["projection_range"][0]["range"]
        with self.assertRaises(SchemaError):
            validate(doc, "doc")

    def test_v5_roundtrip(self):
        validate(v5_doc(), "doc")

    def test_v5_requires_concurrent_section(self):
        doc = v5_doc()
        del doc["concurrent"]
        with self.assertRaises(SchemaError):
            validate(doc, "doc")

    def test_v4_does_not_require_concurrent(self):
        validate(v4_doc(), "doc")  # no concurrent key at all

    def test_concurrent_rows_need_keys(self):
        doc = v5_doc()
        del doc["concurrent"][0]["cache"]
        with self.assertRaises(SchemaError):
            validate(doc, "doc")

    def test_v6_roundtrip(self):
        validate(v6_doc(), "doc")

    def test_v6_requires_entropy_section(self):
        doc = v6_doc()
        del doc["entropy"]
        with self.assertRaises(SchemaError):
            validate(doc, "doc")

    def test_v5_does_not_require_entropy(self):
        validate(v5_doc(), "doc")  # no entropy key at all

    def test_entropy_rows_need_keys(self):
        doc = v6_doc()
        del doc["entropy"][0]["lane"]
        with self.assertRaises(SchemaError):
            validate(doc, "doc")

    def test_v7_roundtrip(self):
        validate(v7_doc(), "doc")

    def test_v7_requires_repack_section(self):
        doc = v7_doc()
        del doc["repack"]
        with self.assertRaises(SchemaError):
            validate(doc, "doc")

    def test_v6_does_not_require_repack(self):
        validate(v6_doc(), "doc")  # no repack key at all

    def test_repack_rows_need_keys(self):
        doc = v7_doc()
        del doc["repack"][0]["lane"]
        with self.assertRaises(SchemaError):
            validate(doc, "doc")

    def test_v8_roundtrip(self):
        validate(v8_doc(), "doc")

    def test_v8_requires_io_backends_section(self):
        doc = v8_doc()
        del doc["io_backends"]
        with self.assertRaises(SchemaError):
            validate(doc, "doc")

    def test_v7_does_not_require_io_backends(self):
        validate(v7_doc(), "doc")  # no io_backends key at all

    def test_io_backends_rows_need_keys(self):
        doc = v8_doc()
        del doc["io_backends"][0]["depth"]
        with self.assertRaises(SchemaError):
            validate(doc, "doc")


class DiffCliTests(unittest.TestCase):
    def test_identical_docs_pass(self):
        with tempfile.TemporaryDirectory() as tmp:
            p = write_doc(tmp, "a.json", v3_doc())
            r = run_diff(p, p)
            self.assertEqual(r.returncode, 0, r.stderr)
            self.assertIn("columnar projection", r.stdout)

    def test_missing_baseline_lane_is_schema_mismatch(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = write_doc(tmp, "base.json", v3_doc())
            new_doc = v3_doc()
            new_doc["projection"] = new_doc["projection"][:1]  # drop lanes
            new = write_doc(tmp, "new.json", new_doc)
            r = run_diff(base, new)
            self.assertEqual(r.returncode, 2, r.stdout)
            self.assertIn("SCHEMA MISMATCH", r.stderr)

    def test_unknown_schema_exits_2(self):
        with tempfile.TemporaryDirectory() as tmp:
            doc = v3_doc()
            doc["schema"] = "bench-codecs/v99"
            p = write_doc(tmp, "bad.json", doc)
            r = run_diff(p, p)
            self.assertEqual(r.returncode, 2)

    def test_v4_docs_print_projection_range_table(self):
        with tempfile.TemporaryDirectory() as tmp:
            p = write_doc(tmp, "a.json", v4_doc())
            r = run_diff(p, p)
            self.assertEqual(r.returncode, 0, r.stderr)
            self.assertIn("entry-range projection", r.stdout)
            self.assertIn("mid50", r.stdout)

    def test_missing_projection_range_lane_is_schema_mismatch(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = write_doc(tmp, "base.json", v4_doc())
            new_doc = v4_doc()
            new_doc["projection_range"] = new_doc["projection_range"][:2]
            new = write_doc(tmp, "new.json", new_doc)
            r = run_diff(base, new)
            self.assertEqual(r.returncode, 2, r.stdout)
            self.assertIn("projection_range", r.stderr)

    def test_v3_baseline_with_v4_new_passes(self):
        # The first run after a schema bump diffs a v3 baseline against a
        # freshly regenerated v4 file — must not fail.
        with tempfile.TemporaryDirectory() as tmp:
            base = write_doc(tmp, "base.json", v3_doc())
            new = write_doc(tmp, "new.json", v4_doc())
            r = run_diff(base, new, "--gate-fastpath", "10")
            self.assertEqual(r.returncode, 0, r.stderr)

    def test_v4_baseline_with_v5_new_passes(self):
        # Same story one bump later: a committed v4 baseline must diff
        # cleanly against the first regenerated v5 artifact.
        with tempfile.TemporaryDirectory() as tmp:
            base = write_doc(tmp, "base.json", v4_doc())
            new = write_doc(tmp, "new.json", v5_doc())
            r = run_diff(base, new, "--gate-fastpath", "10")
            self.assertEqual(r.returncode, 0, r.stderr)

    def test_v5_docs_print_concurrent_table(self):
        with tempfile.TemporaryDirectory() as tmp:
            p = write_doc(tmp, "a.json", v5_doc())
            r = run_diff(p, p)
            self.assertEqual(r.returncode, 0, r.stderr)
            self.assertIn("concurrent scan server", r.stdout)
            self.assertIn("warm", r.stdout)

    def test_missing_concurrent_lane_is_schema_mismatch(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = write_doc(tmp, "base.json", v5_doc())
            new_doc = v5_doc()
            new_doc["concurrent"] = new_doc["concurrent"][:2]
            new = write_doc(tmp, "new.json", new_doc)
            r = run_diff(base, new)
            self.assertEqual(r.returncode, 2, r.stdout)
            self.assertIn("concurrent", r.stderr)

    def test_v5_baseline_with_v6_new_passes(self):
        # The first run after the v6 bump diffs a committed v5 baseline
        # against a freshly regenerated v6 artifact — must not fail.
        with tempfile.TemporaryDirectory() as tmp:
            base = write_doc(tmp, "base.json", v5_doc())
            new = write_doc(tmp, "new.json", v6_doc())
            r = run_diff(base, new, "--gate-fastpath", "10")
            self.assertEqual(r.returncode, 0, r.stderr)

    def test_v6_docs_print_entropy_table(self):
        with tempfile.TemporaryDirectory() as tmp:
            p = write_doc(tmp, "a.json", v6_doc())
            r = run_diff(p, p)
            self.assertEqual(r.returncode, 0, r.stderr)
            self.assertIn("entropy lanes", r.stdout)
            self.assertIn("fse4", r.stdout)
            self.assertIn("huff0", r.stdout)

    def test_missing_entropy_lane_is_schema_mismatch(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = write_doc(tmp, "base.json", v6_doc())
            new_doc = v6_doc()
            new_doc["entropy"] = new_doc["entropy"][:1]
            new = write_doc(tmp, "new.json", new_doc)
            r = run_diff(base, new)
            self.assertEqual(r.returncode, 2, r.stdout)
            self.assertIn("entropy", r.stderr)

    def test_v6_baseline_with_v7_new_passes(self):
        # The first run after the v7 bump diffs a committed v6 baseline
        # against a freshly regenerated v7 artifact — must not fail.
        with tempfile.TemporaryDirectory() as tmp:
            base = write_doc(tmp, "base.json", v6_doc())
            new = write_doc(tmp, "new.json", v7_doc())
            r = run_diff(base, new, "--gate-fastpath", "10")
            self.assertEqual(r.returncode, 0, r.stderr)

    def test_v7_docs_print_repack_table(self):
        with tempfile.TemporaryDirectory() as tmp:
            p = write_doc(tmp, "a.json", v7_doc())
            r = run_diff(p, p)
            self.assertEqual(r.returncode, 0, r.stderr)
            self.assertIn("profile-driven repack", r.stdout)
            self.assertIn("before", r.stdout)
            self.assertIn("after", r.stdout)

    def test_missing_repack_lane_is_schema_mismatch(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = write_doc(tmp, "base.json", v7_doc())
            new_doc = v7_doc()
            new_doc["repack"] = new_doc["repack"][:1]
            new = write_doc(tmp, "new.json", new_doc)
            r = run_diff(base, new)
            self.assertEqual(r.returncode, 2, r.stdout)
            self.assertIn("repack", r.stderr)

    def test_v7_baseline_with_v8_new_passes(self):
        # The first run after the v8 bump diffs a committed v7 baseline
        # against a freshly regenerated v8 artifact — must not fail.
        with tempfile.TemporaryDirectory() as tmp:
            base = write_doc(tmp, "base.json", v7_doc())
            new = write_doc(tmp, "new.json", v8_doc())
            r = run_diff(base, new, "--gate-fastpath", "10")
            self.assertEqual(r.returncode, 0, r.stderr)

    def test_v8_docs_print_io_backends_table(self):
        with tempfile.TemporaryDirectory() as tmp:
            p = write_doc(tmp, "a.json", v8_doc())
            r = run_diff(p, p)
            self.assertEqual(r.returncode, 0, r.stderr)
            self.assertIn("I/O backends", r.stdout)
            self.assertIn("coalesced", r.stdout)
            self.assertIn("remote-sim", r.stdout)

    def test_missing_io_backends_lane_is_schema_mismatch(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = write_doc(tmp, "base.json", v8_doc())
            new_doc = v8_doc()
            new_doc["io_backends"] = new_doc["io_backends"][:2]
            new = write_doc(tmp, "new.json", new_doc)
            r = run_diff(base, new)
            self.assertEqual(r.returncode, 2, r.stdout)
            self.assertIn("io_backends", r.stderr)


class GateTests(unittest.TestCase):
    def test_regression_beyond_gate_exits_3(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = write_doc(tmp, "base.json", v3_doc(speedup=3.0))
            new = write_doc(tmp, "new.json", v3_doc(speedup=2.0))  # -33%
            r = run_diff(base, new, "--gate-fastpath", "10")
            self.assertEqual(r.returncode, 3, r.stdout)
            self.assertIn("PERF REGRESSION", r.stderr)
            self.assertIn("lz4_decode_wildcopy_vs_naive", r.stderr)

    def test_drift_within_gate_passes(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = write_doc(tmp, "base.json", v3_doc(speedup=3.0))
            new = write_doc(tmp, "new.json", v3_doc(speedup=2.8))  # -6.7%
            r = run_diff(base, new, "--gate-fastpath", "10")
            self.assertEqual(r.returncode, 0, r.stderr)
            self.assertIn("no lane regressed", r.stdout)

    def test_placeholder_baseline_never_trips_gate(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = write_doc(tmp, "base.json", v3_doc(with_values=False))
            new = write_doc(tmp, "new.json", v3_doc(speedup=0.5))
            r = run_diff(base, new, "--gate-fastpath", "10")
            self.assertEqual(r.returncode, 0, r.stderr)

    def test_no_gate_flag_never_gates(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = write_doc(tmp, "base.json", v3_doc(speedup=3.0))
            new = write_doc(tmp, "new.json", v3_doc(speedup=1.0))
            r = run_diff(base, new)
            self.assertEqual(r.returncode, 0, r.stderr)


class RoadmapFillTests(unittest.TestCase):
    ROADMAP = (
        "# R\n\nprose\n\n<!-- BENCH_NUMBERS_BEGIN -->\nold\n"
        "<!-- BENCH_NUMBERS_END -->\n\ntail\n"
    )

    def run_fill(self, tmp, doc, roadmap_text):
        bench = write_doc(tmp, "bench.json", doc)
        roadmap = os.path.join(tmp, "ROADMAP.md")
        with open(roadmap, "w") as f:
            f.write(roadmap_text)
        out = os.path.join(tmp, "out.md")
        r = subprocess.run(
            [sys.executable, ROADMAP_FILL, bench, roadmap, "-o", out],
            capture_output=True,
            text=True,
        )
        return r, out

    def test_fills_marker_block_with_all_tables(self):
        with tempfile.TemporaryDirectory() as tmp:
            r, out = self.run_fill(tmp, v8_doc(), self.ROADMAP)
            self.assertEqual(r.returncode, 0, r.stderr)
            with open(out) as f:
                text = f.read()
            self.assertNotIn("\nold\n", text)
            self.assertIn("| fast path |", text)
            self.assertIn("Entropy lanes", text)
            self.assertIn("| fse4 | nanoaod | 1.6 | 420.0 | 700.0 |", text)
            self.assertIn("Read-pipeline scaling", text)
            self.assertIn("Columnar projection", text)
            self.assertIn("| 2of8 | 300.0 | 900.0 | 700.0 |", text)
            self.assertIn("Entry-range projection", text)
            self.assertIn("| mid50 | 910.0 | 680.0 |", text)
            self.assertIn("Concurrent scan server", text)
            self.assertIn("| 8 | 1400.0 | 120.0 | 5200.0 | 30.0 |", text)
            self.assertIn("Profile-driven repack", text)
            self.assertIn("| after | 3808.6 | 900.0 | 1400.0 |", text)
            self.assertIn("I/O backends", text)
            self.assertIn("| coalesced | 0 | 8 | 3 | 950.0 |", text)
            self.assertIn("| remote-sim | 10 | 32 | 96 | 310.0 |", text)
            self.assertIn("tail", text)

    def test_v3_doc_fills_without_projection_range(self):
        with tempfile.TemporaryDirectory() as tmp:
            r, out = self.run_fill(tmp, v3_doc(), self.ROADMAP)
            self.assertEqual(r.returncode, 0, r.stderr)
            with open(out) as f:
                text = f.read()
            self.assertIn("Columnar projection", text)
            self.assertNotIn("Entry-range projection", text)

    def test_v4_doc_fills_without_concurrent(self):
        with tempfile.TemporaryDirectory() as tmp:
            r, out = self.run_fill(tmp, v4_doc(), self.ROADMAP)
            self.assertEqual(r.returncode, 0, r.stderr)
            with open(out) as f:
                text = f.read()
            self.assertIn("Entry-range projection", text)
            self.assertNotIn("Concurrent scan server", text)

    def test_placeholder_doc_renders_placeholders(self):
        with tempfile.TemporaryDirectory() as tmp:
            r, out = self.run_fill(tmp, v8_doc(with_values=False), self.ROADMAP)
            self.assertEqual(r.returncode, 0, r.stderr)
            with open(out) as f:
                text = f.read()
            self.assertIn("placeholder", text)
            self.assertIn("entropy lanes present but unfilled", text)
            self.assertIn("projection lanes present but unfilled", text)
            self.assertIn("projection_range lanes present but unfilled", text)
            self.assertIn("concurrent lanes present but unfilled", text)
            self.assertIn("repack lanes present but unfilled", text)
            self.assertIn("io_backends lanes present but unfilled", text)

    def test_v5_doc_fills_without_entropy(self):
        with tempfile.TemporaryDirectory() as tmp:
            r, out = self.run_fill(tmp, v5_doc(), self.ROADMAP)
            self.assertEqual(r.returncode, 0, r.stderr)
            with open(out) as f:
                text = f.read()
            self.assertIn("Concurrent scan server", text)
            self.assertNotIn("Entropy lanes", text)

    def test_v6_doc_fills_without_repack(self):
        with tempfile.TemporaryDirectory() as tmp:
            r, out = self.run_fill(tmp, v6_doc(), self.ROADMAP)
            self.assertEqual(r.returncode, 0, r.stderr)
            with open(out) as f:
                text = f.read()
            self.assertIn("Entropy lanes", text)
            self.assertNotIn("Profile-driven repack", text)

    def test_v7_doc_fills_without_io_backends(self):
        with tempfile.TemporaryDirectory() as tmp:
            r, out = self.run_fill(tmp, v7_doc(), self.ROADMAP)
            self.assertEqual(r.returncode, 0, r.stderr)
            with open(out) as f:
                text = f.read()
            self.assertIn("Profile-driven repack", text)
            self.assertNotIn("I/O backends", text)

    def test_missing_markers_exit_1(self):
        with tempfile.TemporaryDirectory() as tmp:
            r, _ = self.run_fill(tmp, v3_doc(), "# R\nno markers here\n")
            self.assertEqual(r.returncode, 1)
            self.assertIn("markers", r.stderr)


if __name__ == "__main__":
    unittest.main(verbosity=2)
