#!/usr/bin/env python3
"""Executable companion to docs/FORMAT.md: builds the spec's worked-example
RFIL v3 file byte-by-byte from the *document's* rules (not from the Rust
code), checks structural invariants (record lengths, trailer offset), and
verifies the result is byte-identical to the hex dump embedded in
docs/FORMAT.md §10 — so an edit to either the spec rules or the dump that
breaks their agreement fails CI.

This is the Python-oracle verification artifact for the format book: if the
spec drifts from the writer, regenerating this dump and diffing it against a
file produced by `rootio write` (or `write_tree_serial`) will show exactly
where. Run: python3 python/tests/format_example.py
"""

import os
import re
import struct
import sys


def uvarint(v):
    out = bytearray()
    while True:
        b = v & 0x7F
        v >>= 7
        if v == 0:
            out.append(b)
            return bytes(out)
        out.append(b | 0x80)


def lp(data):
    return uvarint(len(data)) + data


def span_header(tag, level, comp_len, uncomp_len, precond_byte):
    """FORMAT.md §6: 10-byte compressed-span header."""
    assert len(tag) == 2
    h = bytearray(tag)
    h.append(level & 0x0F)
    h += comp_len.to_bytes(3, "little")
    h += uncomp_len.to_bytes(3, "little")
    h.append(precond_byte)
    return bytes(h)


def record(kind, payload):
    """FORMAT.md §3: u32_be total_len | u8 kind | payload."""
    return (len(payload) + 5).to_bytes(4, "big") + bytes([kind]) + payload


def build_example():
    # One branch "x" of type F32 (code 0), three entries 1.0, 2.0, 3.0,
    # default settings = uncompressed (packed setting 0), one basket.
    data = b"".join(struct.pack(">f", v) for v in [1.0, 2.0, 3.0])
    assert len(data) == 12

    # §5 basket record payload: framing prefix + basket header + engine blob.
    basket_payload = (
        uvarint(0)            # branch_id
        + uvarint(0)          # basket_index
        + uvarint(3)          # n_entries
        + uvarint(12)         # data_len
        + uvarint(0)          # n_offsets
        # §6 engine blob: one raw span ("RW"), precond byte 0.
        + span_header(b"RW", 0, 12, 12, 0)
        + data
    )

    header = b"RFIL" + (3).to_bytes(2, "big")   # §2
    basket_offset = len(header)                  # first record at offset 6
    basket_rec = record(1, basket_payload)

    meta_offset = basket_offset + len(basket_rec)
    # §4 TreeMeta payload.
    meta_payload = (
        lp(b"T")              # tree name
        + uvarint(1)          # n_branches
        + lp(b"x") + bytes([0]) + bytes([0])   # branch: name, type F32, no per-branch settings
        + uvarint(0)          # default packed setting (0 = uncompressed)
        + bytes([0])          # default precond byte
        + uvarint(3)          # n_entries
        + bytes([0])          # dictionary flag: none
        + uvarint(1)          # n_baskets
        # BasketLoc: branch_id, basket_index, first_entry, n_entries,
        #            file_offset, compressed_len, uncompressed_len
        + uvarint(0) + uvarint(0) + uvarint(0) + uvarint(3)
        + uvarint(basket_offset) + uvarint(len(basket_rec) - 5) + uvarint(12)
    )
    meta_rec = record(2, meta_payload)

    trailer = meta_offset.to_bytes(8, "big") + b"RFILEND1"   # §2

    blob = header + basket_rec + meta_rec + trailer

    # Structural checks the spec promises.
    assert blob[:4] == b"RFIL" and blob[4:6] == b"\x00\x03"
    assert blob[-8:] == b"RFILEND1"
    assert int.from_bytes(blob[-16:-8], "big") == meta_offset
    total = int.from_bytes(blob[basket_offset : basket_offset + 4], "big")
    assert total == len(basket_payload) + 5 and blob[basket_offset + 4] == 1
    return blob, basket_offset, meta_offset


def hexdump(blob):
    lines = []
    for i in range(0, len(blob), 16):
        chunk = blob[i : i + 16]
        hexs = " ".join(f"{b:02x}" for b in chunk)
        lines.append(f"{i:08x}  {hexs:<47}")
    return "\n".join(lines)


DUMP_LINE = re.compile(r"^([0-9a-f]{8})\s+((?:[0-9a-f]{2}[\s]*)+)$")


def bytes_from_format_md(path):
    """Extract the §10 worked-example bytes from docs/FORMAT.md's hex dump
    (offset-prefixed lines inside the section's code fence; the mid-line
    byte grouping is irrelevant — every 2-hex-digit token counts)."""
    out = bytearray()
    in_section = False
    for line in open(path):
        if line.startswith("## 10."):
            in_section = True
        elif in_section and line.startswith("## "):
            break
        if not in_section:
            continue
        m = DUMP_LINE.match(line.strip())
        if m:
            assert int(m.group(1), 16) == len(out), f"dump offset gap at {m.group(1)}"
            out += bytes.fromhex("".join(m.group(2).split()))
    return bytes(out)


if __name__ == "__main__":
    blob, basket_off, meta_off = build_example()
    print(f"total {len(blob)} bytes; basket record @ {basket_off}, metadata record @ {meta_off}")
    print(hexdump(blob))
    fmt_md = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..", "docs", "FORMAT.md")
    documented = bytes_from_format_md(fmt_md)
    if documented != blob:
        print("MISMATCH: docs/FORMAT.md §10 dump disagrees with the bytes built "
              "from the spec's rules", file=sys.stderr)
        for i, (a, b) in enumerate(zip(documented, blob)):
            if a != b:
                print(f"  first diff at offset {i:#04x}: doc {a:02x} != built {b:02x}",
                      file=sys.stderr)
                break
        print(f"  doc {len(documented)} bytes, built {len(blob)} bytes", file=sys.stderr)
        sys.exit(1)
    print(f"docs/FORMAT.md §10 dump matches ({len(blob)} bytes)")
