#!/usr/bin/env python3
"""Diff two BENCH_codecs.json files and print a per-lane speedup summary.

Usage:
    python3 python/bench_diff.py BASELINE.json NEW.json

Used by CI: the committed BENCH_codecs.json is the baseline, the file the
bench job just regenerated is NEW. Prints

  * the `fast_path_speedups` table of NEW (one row per optimized lane:
    fast MB/s, naive-reference MB/s, speedup factor),
  * per-(payload, setting) compress/decompress throughput deltas vs the
    baseline where both sides have real numbers.

Placeholder baselines (a fresh PR authored without a local rust toolchain
commits `results: []`) are handled gracefully: the script then only prints
the NEW summary. Exit code is always 0 — the diff is informational; the
equivalence guarantees are enforced by `cargo test`, not by thresholds.
"""

import json
import sys


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_diff: cannot read {path}: {e}")
        return None


def fmt_mbps(v):
    return f"{v:9.1f}" if isinstance(v, (int, float)) else f"{'-':>9}"


def speedup_table(doc, title):
    rows = doc.get("fast_path_speedups") or []
    print(f"\n== {title}: fast-path speedups ({len(rows)} lanes) ==")
    if not rows:
        print("  (none recorded — placeholder file?)")
        return {}
    print(f"  {'lane':<44} {'payload':<14} {'fast':>9} {'naive':>9} {'speedup':>8}")
    out = {}
    for r in rows:
        name, payload = r.get("name", "?"), r.get("payload", "?")
        fast, ref, spd = r.get("fast_MBps"), r.get("reference_MBps"), r.get("speedup")
        spd_s = f"{spd:7.2f}x" if isinstance(spd, (int, float)) else "       -"
        print(f"  {name:<44} {payload:<14} {fmt_mbps(fast)} {fmt_mbps(ref)} {spd_s}")
        out[(name, payload)] = spd
    return out


def result_key(r):
    return (r.get("payload"), r.get("setting"))


def main():
    if len(sys.argv) != 3:
        print(__doc__)
        return 0
    base, new = load(sys.argv[1]), load(sys.argv[2])
    if new is None:
        return 0

    new_spd = speedup_table(new, "current run")
    if base is not None:
        base_spd = speedup_table(base, "committed baseline")
        common = [k for k in new_spd if k in base_spd
                  and isinstance(new_spd[k], (int, float))
                  and isinstance(base_spd[k], (int, float))]
        if common:
            print("\n== speedup drift vs baseline ==")
            for k in sorted(common):
                d = new_spd[k] - base_spd[k]
                print(f"  {k[0]:<44} {k[1]:<14} {base_spd[k]:6.2f}x -> {new_spd[k]:6.2f}x ({d:+.2f})")

        base_rows = {result_key(r): r for r in (base.get("results") or [])}
        new_rows = {result_key(r): r for r in (new.get("results") or [])}
        common = sorted(k for k in new_rows if k in base_rows)
        if common:
            print(f"\n== codec-grid throughput drift vs baseline ({len(common)} cells) ==")
            print(f"  {'payload':<10} {'setting':<28} {'compress':>18} {'decompress':>18}")
            for k in common:
                b, n = base_rows[k], new_rows[k]
                def delta(field):
                    bv, nv = b.get(field), n.get(field)
                    if isinstance(bv, (int, float)) and isinstance(nv, (int, float)) and bv:
                        return f"{bv:7.1f}->{nv:7.1f}"
                    return f"{'-':>16}"
                print(f"  {k[0] or '?':<10} {k[1] or '?':<28} {delta('compress_MBps'):>18} {delta('decompress_MBps'):>18}")
        elif not base.get("results"):
            print("\n(baseline has no codec-grid results — placeholder; skipping drift table)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
