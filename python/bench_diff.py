#!/usr/bin/env python3
"""Diff two BENCH_codecs.json files and print a per-lane speedup summary.

Usage:
    python3 python/bench_diff.py BASELINE.json NEW.json

Used by CI: the committed BENCH_codecs.json is the baseline, the file the
bench job just regenerated is NEW. Prints

  * the `fast_path_speedups` table of NEW (one row per optimized lane:
    fast MB/s, naive-reference MB/s, speedup factor),
  * the `read_pipeline` scaling table of NEW (serial oracle vs 1/2/4
    decode workers, per setting),
  * per-(payload, setting) compress/decompress throughput deltas vs the
    baseline where both sides have real numbers.

Placeholder baselines (a fresh PR authored without a local rust toolchain
commits null MB/s fields) are fine: the script then only prints the NEW
summary. What is NOT fine is a schema mismatch — an unknown schema tag, a
missing section, or a lane present in the baseline but absent from the
regenerated file. Those exit non-zero so CI fails loudly instead of
silently skipping lanes; throughput *values* are never thresholded (the
equivalence guarantees are enforced by `cargo test`, not by numbers).

The document schema is specified in docs/BENCHMARKS.md.
"""

import json
import sys

KNOWN_SCHEMAS = ("bench-codecs/v1", "bench-codecs/v2")


class SchemaError(Exception):
    pass


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise SchemaError(f"cannot read {path}: {e}")


def validate(doc, path):
    """Structural validation; raises SchemaError on any mismatch."""
    if not isinstance(doc, dict):
        raise SchemaError(f"{path}: top level is not an object")
    schema = doc.get("schema")
    if schema not in KNOWN_SCHEMAS:
        raise SchemaError(
            f"{path}: unknown schema {schema!r} (known: {', '.join(KNOWN_SCHEMAS)})"
        )
    for key, row_keys in [
        ("results", ("payload", "setting")),
        ("fast_path_speedups", ("name", "payload")),
    ]:
        rows = doc.get(key)
        if not isinstance(rows, list):
            raise SchemaError(f"{path}: missing or non-list section {key!r}")
        for i, r in enumerate(rows):
            if not isinstance(r, dict) or any(k not in r for k in row_keys):
                raise SchemaError(f"{path}: {key}[{i}] lacks keys {row_keys}")
    if schema == "bench-codecs/v2":
        rows = doc.get("read_pipeline")
        if not isinstance(rows, list):
            raise SchemaError(f"{path}: v2 document missing 'read_pipeline' section")
        for i, r in enumerate(rows):
            if not isinstance(r, dict) or "setting" not in r or "workers" not in r:
                raise SchemaError(f"{path}: read_pipeline[{i}] lacks setting/workers")
    return doc


def fmt_mbps(v):
    return f"{v:9.1f}" if isinstance(v, (int, float)) else f"{'-':>9}"


def speedup_table(doc, title):
    rows = doc.get("fast_path_speedups") or []
    print(f"\n== {title}: fast-path speedups ({len(rows)} lanes) ==")
    if not rows:
        print("  (none recorded — placeholder file?)")
        return {}
    print(f"  {'lane':<44} {'payload':<14} {'fast':>9} {'naive':>9} {'speedup':>8}")
    out = {}
    for r in rows:
        name, payload = r.get("name", "?"), r.get("payload", "?")
        fast, ref, spd = r.get("fast_MBps"), r.get("reference_MBps"), r.get("speedup")
        spd_s = f"{spd:7.2f}x" if isinstance(spd, (int, float)) else "       -"
        print(f"  {name:<44} {payload:<14} {fmt_mbps(fast)} {fmt_mbps(ref)} {spd_s}")
        out[(name, payload)] = spd
    return out


def read_pipeline_table(doc, title):
    rows = doc.get("read_pipeline") or []
    if not rows:
        return {}
    print(f"\n== {title}: read-pipeline scaling ({len(rows)} lanes) ==")
    print(f"  {'setting':<28} {'workers':>8} {'read':>9}")
    out = {}
    for r in rows:
        setting, workers = r.get("setting", "?"), r.get("workers", "?")
        w_s = "serial" if workers == 0 else str(workers)
        print(f"  {setting:<28} {w_s:>8} {fmt_mbps(r.get('MBps'))}")
        out[(setting, workers)] = r.get("MBps")
    return out


def check_lane_coverage(base_lanes, new_lanes, what):
    """A lane in the committed baseline that the regenerated file no longer
    produces means the bench and its baseline have drifted apart — fail."""
    missing = [k for k in base_lanes if k not in new_lanes]
    if missing:
        raise SchemaError(
            f"{what}: {len(missing)} baseline lane(s) missing from regenerated file: "
            + ", ".join(str(k) for k in sorted(missing)[:8])
        )


def result_key(r):
    return (r.get("payload"), r.get("setting"))


def main():
    if len(sys.argv) != 3:
        print(__doc__)
        return 0
    base = validate(load(sys.argv[1]), sys.argv[1])
    new = validate(load(sys.argv[2]), sys.argv[2])

    new_spd = speedup_table(new, "current run")
    new_read = read_pipeline_table(new, "current run")

    base_spd = speedup_table(base, "committed baseline")
    base_read = read_pipeline_table(base, "committed baseline")
    check_lane_coverage(base_spd, new_spd, "fast_path_speedups")
    check_lane_coverage(base_read, new_read, "read_pipeline")

    common = [k for k in new_spd if k in base_spd
              and isinstance(new_spd[k], (int, float))
              and isinstance(base_spd[k], (int, float))]
    if common:
        print("\n== speedup drift vs baseline ==")
        for k in sorted(common):
            d = new_spd[k] - base_spd[k]
            print(f"  {k[0]:<44} {k[1]:<14} {base_spd[k]:6.2f}x -> {new_spd[k]:6.2f}x ({d:+.2f})")

    common = [k for k in new_read if k in base_read
              and isinstance(new_read[k], (int, float))
              and isinstance(base_read[k], (int, float))]
    if common:
        print("\n== read-pipeline drift vs baseline ==")
        for k in sorted(common):
            w_s = "serial" if k[1] == 0 else f"{k[1]}w"
            print(f"  {k[0]:<28} {w_s:>8} {base_read[k]:8.1f} -> {new_read[k]:8.1f} MB/s")

    base_rows = {result_key(r): r for r in (base.get("results") or [])}
    new_rows = {result_key(r): r for r in (new.get("results") or [])}
    common = sorted(k for k in new_rows if k in base_rows)
    if common:
        print(f"\n== codec-grid throughput drift vs baseline ({len(common)} cells) ==")
        print(f"  {'payload':<10} {'setting':<28} {'compress':>18} {'decompress':>18}")
        for k in common:
            b, n = base_rows[k], new_rows[k]
            def delta(field):
                bv, nv = b.get(field), n.get(field)
                if isinstance(bv, (int, float)) and isinstance(nv, (int, float)) and bv:
                    return f"{bv:7.1f}->{nv:7.1f}"
                return f"{'-':>16}"
            print(f"  {k[0] or '?':<10} {k[1] or '?':<28} {delta('compress_MBps'):>18} {delta('decompress_MBps'):>18}")
    elif not base.get("results"):
        print("\n(baseline has no codec-grid results — placeholder; skipping drift table)")
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except SchemaError as e:
        print(f"bench_diff: SCHEMA MISMATCH: {e}", file=sys.stderr)
        sys.exit(2)
