#!/usr/bin/env python3
"""Diff two BENCH_codecs.json files and print a per-lane speedup summary.

Usage:
    python3 python/bench_diff.py BASELINE.json NEW.json [--gate-fastpath PCT]

Used by CI: the committed BENCH_codecs.json is the baseline, the file the
bench job just regenerated is NEW. Prints

  * the `fast_path_speedups` table of NEW (one row per optimized lane:
    fast MB/s, naive-reference MB/s, speedup factor),
  * the `entropy` table of NEW (fse2 / fse4 / huff0 coder lanes: ratio,
    encode and decode MB/s per payload),
  * the `read_pipeline` scaling table of NEW (serial oracle vs 1/2/4
    decode workers, per setting),
  * the `projection` table of NEW (2of8 / 8of8 branch projections:
    serial vs offset-sorted vs submission-order prefetch),
  * the `projection_range` table of NEW (entry-range slices: full tree vs
    the middle-50% window, offset vs submission prefetch),
  * the `concurrent` table of NEW (scan-server waves of 1/8/64 queries:
    aggregate MB/s and p99 latency, cold vs warm decoded-basket cache),
  * the `repack` table of NEW (file size + full/hot-subset read MB/s
    before and after a profile-driven `rootio repack`),
  * the `io_backends` table of NEW (physical reads per full sweep for
    the pread/coalesced/mmap backends, plus the remote-sim latency x
    prefetch-depth throughput surface),
  * per-(payload, setting) compress/decompress throughput deltas vs the
    baseline where both sides have real numbers.

Placeholder baselines (a fresh PR authored without a local rust toolchain
commits null MB/s fields) are fine: the script then only prints the NEW
summary. What is NOT fine is a schema mismatch — an unknown schema tag, a
missing section, or a lane present in the baseline but absent from the
regenerated file. Those exit 2 so CI fails loudly instead of silently
skipping lanes.

Gating: raw MB/s values are machine-noise-sensitive and are never
thresholded. The fast-path *speedup factors* (fast/reference measured in
the same run, so machine noise cancels) ARE gated when `--gate-fastpath
PCT` is passed: a lane whose speedup drops more than PCT percent below a
numeric baseline exits 3 — perf is a CI gate, not a log line. Null
(placeholder) baselines never trip the gate.

The document schema is specified in docs/BENCHMARKS.md.
"""

import argparse
import json
import sys

KNOWN_SCHEMAS = (
    "bench-codecs/v1",
    "bench-codecs/v2",
    "bench-codecs/v3",
    "bench-codecs/v4",
    "bench-codecs/v5",
    "bench-codecs/v6",
    "bench-codecs/v7",
    "bench-codecs/v8",
)


class SchemaError(Exception):
    pass


class RegressionError(Exception):
    pass


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise SchemaError(f"cannot read {path}: {e}")


def validate(doc, path):
    """Structural validation; raises SchemaError on any mismatch."""
    if not isinstance(doc, dict):
        raise SchemaError(f"{path}: top level is not an object")
    schema = doc.get("schema")
    if schema not in KNOWN_SCHEMAS:
        raise SchemaError(
            f"{path}: unknown schema {schema!r} (known: {', '.join(KNOWN_SCHEMAS)})"
        )
    required = [
        ("results", ("payload", "setting")),
        ("fast_path_speedups", ("name", "payload")),
    ]
    # Each schema bump adds one section; KNOWN_SCHEMAS is ordered, so the
    # tag's index tells us which sections must be present.
    version = KNOWN_SCHEMAS.index(schema) + 1
    if version >= 2:
        required.append(("read_pipeline", ("setting", "workers")))
    if version >= 3:
        required.append(("projection", ("branches", "order", "workers")))
    if version >= 4:
        required.append(("projection_range", ("range", "order", "workers")))
    if version >= 5:
        required.append(("concurrent", ("queries", "cache")))
    if version >= 6:
        required.append(("entropy", ("lane", "payload")))
    if version >= 7:
        required.append(("repack", ("lane",)))
    if version >= 8:
        required.append(("io_backends", ("backend", "latency_ms", "depth")))
    for key, row_keys in required:
        rows = doc.get(key)
        if not isinstance(rows, list):
            raise SchemaError(f"{path}: missing or non-list section {key!r}")
        for i, r in enumerate(rows):
            if not isinstance(r, dict) or any(k not in r for k in row_keys):
                raise SchemaError(f"{path}: {key}[{i}] lacks keys {row_keys}")
    return doc


def fmt_mbps(v):
    return f"{v:9.1f}" if isinstance(v, (int, float)) else f"{'-':>9}"


def speedup_table(doc, title):
    rows = doc.get("fast_path_speedups") or []
    print(f"\n== {title}: fast-path speedups ({len(rows)} lanes) ==")
    if not rows:
        print("  (none recorded — placeholder file?)")
        return {}
    print(f"  {'lane':<44} {'payload':<14} {'fast':>9} {'naive':>9} {'speedup':>8}")
    out = {}
    for r in rows:
        name, payload = r.get("name", "?"), r.get("payload", "?")
        fast, ref, spd = r.get("fast_MBps"), r.get("reference_MBps"), r.get("speedup")
        spd_s = f"{spd:7.2f}x" if isinstance(spd, (int, float)) else "       -"
        print(f"  {name:<44} {payload:<14} {fmt_mbps(fast)} {fmt_mbps(ref)} {spd_s}")
        out[(name, payload)] = spd
    return out


def read_pipeline_table(doc, title):
    rows = doc.get("read_pipeline") or []
    if not rows:
        return {}
    print(f"\n== {title}: read-pipeline scaling ({len(rows)} lanes) ==")
    print(f"  {'setting':<28} {'workers':>8} {'read':>9}")
    out = {}
    for r in rows:
        setting, workers = r.get("setting", "?"), r.get("workers", "?")
        w_s = "serial" if workers == 0 else str(workers)
        print(f"  {setting:<28} {w_s:>8} {fmt_mbps(r.get('MBps'))}")
        out[(setting, workers)] = r.get("MBps")
    return out


def projection_table(doc, title):
    rows = doc.get("projection") or []
    if not rows:
        return {}
    print(f"\n== {title}: columnar projection ({len(rows)} lanes) ==")
    print(f"  {'projection':<12} {'order':<12} {'workers':>8} {'read':>9}")
    out = {}
    for r in rows:
        branches, order = r.get("branches", "?"), r.get("order", "?")
        workers = r.get("workers", "?")
        w_s = "serial" if workers == 0 else str(workers)
        print(f"  {branches:<12} {order:<12} {w_s:>8} {fmt_mbps(r.get('MBps'))}")
        out[(branches, order, workers)] = r.get("MBps")
    return out


def projection_range_table(doc, title):
    rows = doc.get("projection_range") or []
    if not rows:
        return {}
    print(f"\n== {title}: entry-range projection ({len(rows)} lanes) ==")
    print(f"  {'range':<12} {'order':<12} {'workers':>8} {'read':>9}")
    out = {}
    for r in rows:
        rng, order = r.get("range", "?"), r.get("order", "?")
        workers = r.get("workers", "?")
        print(f"  {rng:<12} {order:<12} {workers!s:>8} {fmt_mbps(r.get('MBps'))}")
        out[(rng, order, workers)] = r.get("MBps")
    return out


def concurrent_table(doc, title):
    rows = doc.get("concurrent") or []
    if not rows:
        return {}
    print(f"\n== {title}: concurrent scan server ({len(rows)} lanes) ==")
    print(f"  {'queries':>8} {'cache':<8} {'aggregate':>9} {'p99 ms':>9}")
    out = {}
    for r in rows:
        queries, cache = r.get("queries", "?"), r.get("cache", "?")
        p99 = r.get("p99_ms")
        p99_s = f"{p99:9.2f}" if isinstance(p99, (int, float)) else f"{'-':>9}"
        print(f"  {queries!s:>8} {cache:<8} {fmt_mbps(r.get('MBps'))} {p99_s}")
        out[(queries, cache)] = r.get("MBps")
    return out


def entropy_table(doc, title):
    rows = doc.get("entropy") or []
    if not rows:
        return {}
    print(f"\n== {title}: entropy lanes ({len(rows)} lanes) ==")
    print(f"  {'lane':<8} {'payload':<14} {'ratio':>7} {'encode':>9} {'decode':>9}")
    out = {}
    for r in rows:
        lane, payload = r.get("lane", "?"), r.get("payload", "?")
        ratio = r.get("ratio")
        ratio_s = f"{ratio:7.3f}" if isinstance(ratio, (int, float)) else f"{'-':>7}"
        print(
            f"  {lane:<8} {payload:<14} {ratio_s} "
            f"{fmt_mbps(r.get('encode_MBps'))} {fmt_mbps(r.get('decode_MBps'))}"
        )
        out[(lane, payload)] = (r.get("encode_MBps"), r.get("decode_MBps"))
    return out


def repack_table(doc, title):
    rows = doc.get("repack") or []
    if not rows:
        return {}
    print(f"\n== {title}: profile-driven repack ({len(rows)} lanes) ==")
    print(f"  {'lane':<8} {'file KB':>10} {'full read':>10} {'hot read':>10}")
    out = {}
    for r in rows:
        lane = r.get("lane", "?")
        fb = r.get("file_bytes")
        fb_s = f"{fb / 1024:10.1f}" if isinstance(fb, (int, float)) else f"{'-':>10}"
        print(f"  {lane:<8} {fb_s} {fmt_mbps(r.get('read_MBps')):>10} "
              f"{fmt_mbps(r.get('hot_MBps')):>10}")
        out[lane] = (fb, r.get("read_MBps"), r.get("hot_MBps"))
    return out


def io_backends_table(doc, title):
    rows = doc.get("io_backends") or []
    if not rows:
        return {}
    print(f"\n== {title}: I/O backends ({len(rows)} lanes) ==")
    print(f"  {'backend':<12} {'lat ms':>7} {'depth':>6} {'reads':>8} {'read':>9}")
    out = {}
    for r in rows:
        backend = r.get("backend", "?")
        lat, depth = r.get("latency_ms", "?"), r.get("depth", "?")
        reads = r.get("reads")
        reads_s = f"{reads:8d}" if isinstance(reads, int) else f"{'-':>8}"
        print(f"  {backend:<12} {lat!s:>7} {depth!s:>6} {reads_s} {fmt_mbps(r.get('MBps'))}")
        out[(backend, lat, depth)] = r.get("MBps")
    return out


def check_lane_coverage(base_lanes, new_lanes, what):
    """A lane in the committed baseline that the regenerated file no longer
    produces means the bench and its baseline have drifted apart — fail."""
    missing = [k for k in base_lanes if k not in new_lanes]
    if missing:
        raise SchemaError(
            f"{what}: {len(missing)} baseline lane(s) missing from regenerated file: "
            + ", ".join(str(k) for k in sorted(missing)[:8])
        )


def check_fastpath_gate(base_spd, new_spd, pct):
    """Fail (exit 3) when any fast-path lane's speedup factor regresses more
    than `pct` percent vs a *numeric* baseline. Speedups are same-run ratios
    (fast vs naive on the same machine), so this is robust to absolute
    machine-speed differences between CI runs."""
    floor = 1.0 - pct / 100.0
    regressed = []
    for k in sorted(base_spd):
        b, n = base_spd.get(k), new_spd.get(k)
        if isinstance(b, (int, float)) and isinstance(n, (int, float)) and n < b * floor:
            regressed.append(f"{k[0]} [{k[1]}]: {b:.2f}x -> {n:.2f}x")
    if regressed:
        raise RegressionError(
            f"{len(regressed)} fast-path lane(s) regressed >{pct:g}% vs baseline:\n  "
            + "\n  ".join(regressed)
        )


def result_key(r):
    return (r.get("payload"), r.get("setting"))


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Diff two BENCH_codecs.json files (see module docstring)."
    )
    ap.add_argument("baseline")
    ap.add_argument("new")
    ap.add_argument(
        "--gate-fastpath",
        type=float,
        default=None,
        metavar="PCT",
        help="exit 3 if any fast-path speedup regresses more than PCT%% "
        "vs a numeric baseline lane",
    )
    args = ap.parse_args(argv)
    base = validate(load(args.baseline), args.baseline)
    new = validate(load(args.new), args.new)

    new_spd = speedup_table(new, "current run")
    new_entropy = entropy_table(new, "current run")
    new_read = read_pipeline_table(new, "current run")
    new_proj = projection_table(new, "current run")
    new_prange = projection_range_table(new, "current run")
    new_conc = concurrent_table(new, "current run")
    new_repack = repack_table(new, "current run")
    new_io = io_backends_table(new, "current run")

    base_spd = speedup_table(base, "committed baseline")
    base_entropy = entropy_table(base, "committed baseline")
    base_read = read_pipeline_table(base, "committed baseline")
    base_proj = projection_table(base, "committed baseline")
    base_prange = projection_range_table(base, "committed baseline")
    base_conc = concurrent_table(base, "committed baseline")
    base_repack = repack_table(base, "committed baseline")
    base_io = io_backends_table(base, "committed baseline")
    check_lane_coverage(base_spd, new_spd, "fast_path_speedups")
    check_lane_coverage(base_entropy, new_entropy, "entropy")
    check_lane_coverage(base_read, new_read, "read_pipeline")
    check_lane_coverage(base_proj, new_proj, "projection")
    check_lane_coverage(base_prange, new_prange, "projection_range")
    check_lane_coverage(base_conc, new_conc, "concurrent")
    check_lane_coverage(base_repack, new_repack, "repack")
    check_lane_coverage(base_io, new_io, "io_backends")

    common = [k for k in new_spd if k in base_spd
              and isinstance(new_spd[k], (int, float))
              and isinstance(base_spd[k], (int, float))]
    if common:
        print("\n== speedup drift vs baseline ==")
        for k in sorted(common):
            d = new_spd[k] - base_spd[k]
            print(f"  {k[0]:<44} {k[1]:<14} {base_spd[k]:6.2f}x -> {new_spd[k]:6.2f}x ({d:+.2f})")

    common = [k for k in new_entropy if k in base_entropy
              and all(isinstance(v, (int, float)) for v in new_entropy[k])
              and all(isinstance(v, (int, float)) for v in base_entropy[k])]
    if common:
        print("\n== entropy-lane drift vs baseline ==")
        for k in sorted(common):
            (be, bd), (ne, nd) = base_entropy[k], new_entropy[k]
            print(f"  {k[0]:<8} {k[1]:<14} enc {be:8.1f} -> {ne:8.1f}  "
                  f"dec {bd:8.1f} -> {nd:8.1f} MB/s")

    common = [k for k in new_read if k in base_read
              and isinstance(new_read[k], (int, float))
              and isinstance(base_read[k], (int, float))]
    if common:
        print("\n== read-pipeline drift vs baseline ==")
        for k in sorted(common):
            w_s = "serial" if k[1] == 0 else f"{k[1]}w"
            print(f"  {k[0]:<28} {w_s:>8} {base_read[k]:8.1f} -> {new_read[k]:8.1f} MB/s")

    common = [k for k in new_proj if k in base_proj
              and isinstance(new_proj[k], (int, float))
              and isinstance(base_proj[k], (int, float))]
    if common:
        print("\n== projection drift vs baseline ==")
        for k in sorted(common):
            w_s = "serial" if k[2] == 0 else f"{k[2]}w"
            print(f"  {k[0]:<12} {k[1]:<12} {w_s:>8} {base_proj[k]:8.1f} -> {new_proj[k]:8.1f} MB/s")

    common = [k for k in new_prange if k in base_prange
              and isinstance(new_prange[k], (int, float))
              and isinstance(base_prange[k], (int, float))]
    if common:
        print("\n== entry-range projection drift vs baseline ==")
        for k in sorted(common):
            print(f"  {k[0]:<12} {k[1]:<12} {k[2]!s:>8} "
                  f"{base_prange[k]:8.1f} -> {new_prange[k]:8.1f} MB/s")

    common = [k for k in new_conc if k in base_conc
              and isinstance(new_conc[k], (int, float))
              and isinstance(base_conc[k], (int, float))]
    if common:
        print("\n== concurrent scan-server drift vs baseline ==")
        for k in sorted(common):
            print(f"  {k[0]!s:>8}q {k[1]:<8} "
                  f"{base_conc[k]:8.1f} -> {new_conc[k]:8.1f} MB/s")

    common = [k for k in new_repack if k in base_repack
              and all(isinstance(v, (int, float)) for v in new_repack[k])
              and all(isinstance(v, (int, float)) for v in base_repack[k])]
    if common:
        print("\n== repack drift vs baseline ==")
        for k in sorted(common):
            (bf, br, bh), (nf, nr, nh) = base_repack[k], new_repack[k]
            print(f"  {k:<8} size {bf / 1024:8.1f} -> {nf / 1024:8.1f} KB  "
                  f"full {br:8.1f} -> {nr:8.1f}  hot {bh:8.1f} -> {nh:8.1f} MB/s")

    common = [k for k in new_io if k in base_io
              and isinstance(new_io[k], (int, float))
              and isinstance(base_io[k], (int, float))]
    if common:
        print("\n== I/O backend drift vs baseline ==")
        for k in sorted(common):
            print(f"  {k[0]:<12} lat={k[1]!s:>3}ms depth={k[2]!s:>3} "
                  f"{base_io[k]:8.1f} -> {new_io[k]:8.1f} MB/s")

    base_rows = {result_key(r): r for r in (base.get("results") or [])}
    new_rows = {result_key(r): r for r in (new.get("results") or [])}
    common = sorted(k for k in new_rows if k in base_rows)
    if common:
        print(f"\n== codec-grid throughput drift vs baseline ({len(common)} cells) ==")
        print(f"  {'payload':<10} {'setting':<28} {'compress':>18} {'decompress':>18}")
        for k in common:
            b, n = base_rows[k], new_rows[k]
            def delta(field):
                bv, nv = b.get(field), n.get(field)
                if isinstance(bv, (int, float)) and isinstance(nv, (int, float)) and bv:
                    return f"{bv:7.1f}->{nv:7.1f}"
                return f"{'-':>16}"
            print(f"  {k[0] or '?':<10} {k[1] or '?':<28} {delta('compress_MBps'):>18} {delta('decompress_MBps'):>18}")
    elif not base.get("results"):
        print("\n(baseline has no codec-grid results — placeholder; skipping drift table)")

    if args.gate_fastpath is not None:
        check_fastpath_gate(base_spd, new_spd, args.gate_fastpath)
        print(f"\nfast-path gate: no lane regressed >{args.gate_fastpath:g}% vs baseline")
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except SchemaError as e:
        print(f"bench_diff: SCHEMA MISMATCH: {e}", file=sys.stderr)
        sys.exit(2)
    except RegressionError as e:
        print(f"bench_diff: PERF REGRESSION: {e}", file=sys.stderr)
        sys.exit(3)
