//! Offline stub of the `xla` crate (xla-rs) API surface used by
//! `rootio::runtime`. The real crate links the PJRT C API and libxla, which
//! cannot be vendored into an offline build; this stub keeps the crate
//! graph compiling and fails *at runtime* with a clear error the callers
//! already handle — `runtime::cpu_client()` is only invoked behind explicit
//! opt-ins (the `--xla` CLI path, the adaptive example's fallback chain) and
//! every integration test skips when `artifacts/` is absent.
//!
//! Swap this out by pointing the workspace `xla` dependency at the real
//! crate when a PJRT toolchain is available; no rootio source changes are
//! needed (the stub mirrors the exact call signatures used).

use std::fmt;

/// Stub error: every entry point returns this.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla (offline stub): {}", self.0)
    }
}
impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error("XLA/PJRT backend not available in this build; use the native analyzer fallback".into())
}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable())
    }
}

/// XLA computation (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_errors_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("offline stub"));
    }
}
