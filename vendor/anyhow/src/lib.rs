//! Minimal, dependency-free reimplementation of the `anyhow` API subset used
//! by this repository, vendored so the workspace builds with no network
//! access. Semantics match upstream for the covered surface:
//!
//! * [`Error`]: an opaque error value carrying a message and a cause chain.
//! * [`Result<T>`]: alias for `Result<T, Error>`.
//! * [`anyhow!`], [`bail!`], [`ensure!`]: format-style constructors.
//! * [`Context`]: `.context(..)` / `.with_context(..)` on `Result` (for any
//!   `std::error::Error`) and on `Option`.
//!
//! Like upstream, `Error` deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket `From` impl coherent.
//! `{:#}` formats the full cause chain (`outer: inner: ...`); `{:?}` formats
//! the anyhow-style multi-line report.

use std::fmt;

/// Opaque error: display message plus an optional cause chain.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `Result` specialized to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message.
    pub fn msg(msg: impl fmt::Display) -> Self {
        Self { msg: msg.to_string(), source: None }
    }

    /// Construct from an ordered chain of messages, outermost first.
    fn from_chain(mut msgs: Vec<String>) -> Self {
        let mut err: Option<Error> = None;
        while let Some(m) = msgs.pop() {
            err = Some(Error { msg: m, source: err.map(Box::new) });
        }
        err.unwrap_or_else(|| Error::msg("unknown error"))
    }

    /// Wrap this error with an outer context message.
    pub fn context(self, msg: impl fmt::Display) -> Self {
        Error { msg: msg.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut msgs = vec![self.msg.as_str()];
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        msgs.into_iter()
    }

    /// The innermost message of the chain.
    pub fn root_cause(&self) -> &str {
        self.chain().last().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            let mut first = true;
            for m in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{m}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut msgs = vec![e.to_string()];
        let mut src = std::error::Error::source(&e);
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        Error::from_chain(msgs)
    }
}

/// Attach context to errors (`Result`) or turn `None` into an error.
pub trait Context<T> {
    fn context<C>(self, ctx: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C>(self, ctx: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::from(e).context(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, ctx: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening file").unwrap_err();
        assert_eq!(format!("{e}"), "opening file");
        assert_eq!(format!("{e:#}"), "opening file: missing");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "slot 3");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "too big: 12");
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }

    #[test]
    fn question_mark_conversion() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "missing");
    }

    #[test]
    fn debug_report_shape() {
        let e = Error::msg("inner").context("middle").context("outer");
        let d = format!("{e:?}");
        assert!(d.starts_with("outer"));
        assert!(d.contains("Caused by:"));
        assert_eq!(e.root_cause(), "inner");
    }
}
